//! Event-exact analytical timing engine.
//!
//! DBB schedules are fully deterministic (paper §V-C: "DBB sparse models
//! have fixed sparsity and easily predictable runtime"), so cycle counts and
//! switching-event counts have closed forms in the design point, the GEMM
//! shape and the (weight, activation) sparsity statistics. The per-cycle
//! [`super::detailed`] engine validates these formulas on small arrays; this
//! engine then sweeps full CNNs across the design space in microseconds.
//!
//! ## Schedule (shared with the detailed engine)
//!
//! Output-stationary tiling: the array computes `(A·M)×(C·N)` output tiles;
//! for each tile pass the whole reduction dimension `K` streams through as
//! `T = ceil(K/B)` block-steps, each occupying `o` cycles:
//!
//! * dense STA: `o = 1` (B-way dot product per cycle);
//! * STA-DBB (b-of-B): `o = 1` while the model density ≤ b/B, else the
//!   dense-fallback `o = ceil(B/b)` sub-passes per block;
//! * STA-VDBB: `o = bound` — the time-unrolled occupancy (paper §III-B).
//!
//! Sub-matrix operands are skewed across the array edges at block
//! granularity. An isolated pass costs `(T + M + N − 2)·o` cycles plus `A·C`
//! output drain cycles; back-to-back passes pipeline (double-buffered
//! accumulators, operands of the next tile follow immediately behind the
//! current tile's wavefront), so a whole GEMM of `P` passes costs
//! `P·T·o + (M + N − 2)·o + A·C`.

use super::{EventCounts, GemmTiming};
use crate::arch::{Datapath, Design};
use crate::dbb::DbbMatrix;
use crate::tensor::TensorI8;

/// Weight-side statistics the timing model needs (derivable from a
/// [`DbbMatrix`] or synthesized for design-space sweeps).
#[derive(Debug, Clone, Copy)]
pub struct WeightStats {
    /// Reduction dim of the dense matrix.
    pub k: usize,
    /// Output channels.
    pub n: usize,
    /// Block size the matrix is encoded with (must equal `design.dims.b`
    /// for sparse datapaths).
    pub bz: usize,
    /// Density bound (max NNZ/block) of the encoding.
    pub bound: usize,
    /// Total stored non-zeros (for weight-zero padding-slot accounting).
    pub total_nnz: u64,
}

impl WeightStats {
    /// Extract from an encoded matrix.
    pub fn of(w: &DbbMatrix) -> Self {
        WeightStats {
            k: w.k,
            n: w.n,
            bz: w.bz,
            bound: w.bound,
            total_nnz: w.total_nnz() as u64,
        }
    }

    /// Extract from a BSR operand. For the BSR datapath `bound/bz` is the
    /// *block* density (fraction of the block grid that survived pruning),
    /// so `bound` is the measured block density rounded to the nearest
    /// `1/bz` — exact whenever the pruner keeps `keep`-of-`nbc` blocks
    /// with `keep/nbc` on the `1/bz` grid (the engine's matched-sparsity
    /// budgets are).
    pub fn of_bsr(w: &crate::gemm::BsrPacked) -> Self {
        let bz = w.bz_r;
        let bound = ((w.block_density() * bz as f64).round() as usize).clamp(1, bz);
        WeightStats {
            k: w.k,
            n: w.n,
            bz,
            bound,
            total_nnz: w.total_nnz() as u64,
        }
    }

    /// Synthetic stats for a matrix pruned exactly to `bound`-of-`bz`
    /// (every block full to the bound — the design-space sweep assumption).
    pub fn synthetic(k: usize, n: usize, bz: usize, bound: usize) -> Self {
        let kblocks = k.div_ceil(bz) as u64;
        WeightStats {
            k,
            n,
            bz,
            bound,
            total_nnz: kblocks * n as u64 * bound as u64,
        }
    }

    /// K-blocks per column.
    pub fn kblocks(&self) -> usize {
        self.k.div_ceil(self.bz)
    }

    /// Weight density (bound / bz).
    pub fn density(&self) -> f64 {
        self.bound as f64 / self.bz as f64
    }
}

/// Block occupancy `o` for a design running a weight matrix with `stats`.
pub fn occupancy(design: &Design, stats: &WeightStats) -> usize {
    match design.datapath {
        Datapath::Dense => 1,
        Datapath::FixedDbb { b } => {
            if stats.bound <= b {
                1
            } else {
                // dense fallback: stream each B-block as ceil(B/b) compressed
                // sub-blocks of b
                design.dims.b.div_ceil(b)
            }
        }
        Datapath::Vdbb => stats.bound.max(1),
        // a surviving BSR block is a dense B-way dot product: 1 cycle,
        // exactly like the dense STA — the win is *skipped* block-steps
        // (see [`sched_blocks`]), not per-block occupancy
        Datapath::Bsr => 1,
    }
}

/// Reduction block-steps the *schedule* streams: dense datapaths stream
/// K in chunks of their own inner dim B (1 for the scalar SA); sparse
/// datapaths stream the DBB encoding's k-blocks.
pub fn sched_blocks(design: &Design, stats: &WeightStats) -> usize {
    match design.datapath {
        Datapath::Dense => stats.k.div_ceil(design.dims.b),
        // the BSR scheduler walks `row_ptr`/`col_idx` and only ever
        // streams surviving blocks: kblocks × block-density (for BSR
        // layers `stats.density() = bound/bz` *is* the block density)
        Datapath::Bsr => (stats.kblocks() * stats.bound).div_ceil(stats.bz).max(1),
        _ => stats.kblocks(),
    }
}

/// MAC issue slots per (row, block-step) pair on one output column — how
/// many physical-MAC cycles a block occupies per output element.
fn slots_per_block(design: &Design, stats: &WeightStats) -> u64 {
    match design.datapath {
        // B MACs' worth, 1 cycle of B-way DP (BSR: per *surviving* block)
        Datapath::Dense | Datapath::Bsr => design.dims.b as u64,
        Datapath::FixedDbb { b } => (occupancy(design, stats) * b) as u64,
        Datapath::Vdbb => occupancy(design, stats) as u64,
    }
}

/// Cycle count for one *isolated* output-tile pass (skew fill + stream +
/// accumulator drain). Back-to-back passes pipeline: see [`gemm_cycles`].
pub fn cycles_per_pass(design: &Design, stats: &WeightStats) -> u64 {
    let d = design.dims;
    let t = sched_blocks(design, stats) as u64;
    let o = occupancy(design, stats) as u64;
    let skew = (d.m + d.n - 2) as u64;
    (t + skew) * o + (d.a * d.c) as u64
}

/// Steady-state cycles per pass when passes stream back-to-back: the next
/// tile's operands enter the edge as soon as the current tile's last block
/// has entered, so the skew wavefronts of consecutive passes coexist in the
/// array (standard double-buffered output-stationary operation; the paper's
/// 4-TOPS nominal rating presumes this).
pub fn steady_cycles_per_pass(design: &Design, stats: &WeightStats) -> u64 {
    sched_blocks(design, stats) as u64 * occupancy(design, stats) as u64
}

/// Total cycles for `passes` back-to-back output-tile passes: steady-state
/// streaming plus one pipeline fill (skew) and one final drain.
pub fn gemm_cycles(design: &Design, stats: &WeightStats, passes: u64) -> u64 {
    if passes == 0 {
        return 0;
    }
    let d = design.dims;
    let o = occupancy(design, stats) as u64;
    let skew = (d.m + d.n - 2) as u64;
    passes * steady_cycles_per_pass(design, stats) + skew * o + (d.a * d.c) as u64
}

/// Full timing for a `mg×k×n` GEMM with the given weight statistics and a
/// *measured* activation-zero fraction (`act_sparsity ∈ [0,1]`).
///
/// `im2col_magnification ≥ 1` divides activation SRAM traffic (the hardware
/// IM2COL unit, paper §IV-C); pass 1.0 for FC/pointwise layers or designs
/// without the unit. Activations stream *raw*; see
/// [`gemm_timing_stats_enc`] for the A-side-DBB-encoded variant.
pub fn gemm_timing_stats(
    design: &Design,
    mg: usize,
    stats: &WeightStats,
    act_sparsity: f64,
    im2col_magnification: f64,
) -> GemmTiming {
    gemm_timing_stats_enc(design, mg, stats, act_sparsity, im2col_magnification, false)
}

/// [`gemm_timing_stats`] with an explicit A-side stream encoding flag —
/// how the twin prices "never fetched the operand" separately from
/// "skipped the multiply". With `act_encoded` the activation SRAM traffic
/// is the DBB-compressed stream: only the `(1 − act_sparsity)` surviving
/// values are fetched (`act_sram_bytes`) plus 1 bit per logical element of
/// positional bitmask (`act_index_bytes` — `bz` bits per `bz`-block).
/// Everything else — cycles, MAC gating, weight traffic, the pre-magnifier
/// edge demand `act_edge_bytes` — is identical: compression changes what
/// the SRAM serves, not what the schedule executes (the datapath still
/// gates the same zero-activation MACs; those stay priced in
/// `macs_gated`). Note the break-even: a dense operand (`act_sparsity ≈
/// 0`) costs *more* encoded than raw (the index overhead buys nothing),
/// which is exactly why [`crate::gemm::ActPolicy::Auto`] only encodes
/// above [`crate::gemm::ActPolicy::ENCODE_THRESHOLD`].
///
/// A [`Datapath::Dense`] array has no DBB decoder on either operand edge,
/// so `act_encoded` is ignored there and the raw stream is priced — which
/// keeps baseline-normalized comparisons (Fig. 11) honest when one profile
/// set is shared across design points.
pub fn gemm_timing_stats_enc(
    design: &Design,
    mg: usize,
    stats: &WeightStats,
    act_sparsity: f64,
    im2col_magnification: f64,
    act_encoded: bool,
) -> GemmTiming {
    let d = design.dims;
    assert!(
        matches!(design.datapath, Datapath::Dense) || d.b == stats.bz,
        "sparse datapath block size {} != encoding {}",
        d.b,
        stats.bz
    );
    let (tile_rows, tile_cols) = (d.a * d.m, d.c * d.n);
    let row_tiles = mg.div_ceil(tile_rows) as u64;
    let col_tiles = stats.n.div_ceil(tile_cols) as u64;
    let passes = row_tiles * col_tiles;
    let cycles = gemm_cycles(design, stats, passes);

    // ---- issued MAC slots ----
    // every in-bounds (row, block, col) triple issues `slots_per_block`
    // physical-MAC cycles; out-of-bounds tile padding leaves MACs idle.
    let kb = sched_blocks(design, stats) as u64;
    let triples = mg as u64 * kb * stats.n as u64;
    let spb = slots_per_block(design, stats);
    let issued = triples * spb;

    // weight-zero slots within issued work (encoded padding):
    //   total weight slots streamed per column = kb * slots_of_weights,
    //   of which total_nnz carry real values. Dense datapaths stream the
    //   raw K values (zeros included — they issue but don't switch).
    let weight_slots_per_col: u64 = kb
        * match design.datapath {
            Datapath::Dense | Datapath::Bsr => design.dims.b as u64,
            Datapath::FixedDbb { b } => (occupancy(design, stats) * b) as u64,
            Datapath::Vdbb => occupancy(design, stats) as u64,
        };
    let dense_k_pad = kb * design.dims.b as u64; // K padded to block multiple
    let real_weight_slots = match design.datapath {
        // dense: non-zero weights = total_nnz, pad K-B zeros also stream.
        // BSR: zeros embedded in surviving blocks stream but never switch,
        // so real slots are again exactly total_nnz.
        Datapath::Dense | Datapath::Bsr => stats.total_nnz,
        _ => stats.total_nnz,
    };
    let wzero_frac = if weight_slots_per_col == 0 {
        0.0
    } else {
        1.0 - (real_weight_slots as f64 / (weight_slots_per_col * stats.n as u64) as f64)
    };
    let _ = dense_k_pad;

    // act-zero gating applies to slots with a real weight; weight-zero slots
    // are always non-switching. Both land in `macs_gated`.
    let real_slots = issued as f64 * (1.0 - wzero_frac);
    let active = real_slots * (1.0 - act_sparsity);
    let gated = issued as f64 - active;

    // ---- idle slots: physical_macs × cycles − issued ----
    let slots = design.physical_macs() as u64 * cycles;
    let idle = slots.saturating_sub(issued);

    // ---- SRAM traffic ----
    // weights re-stream once per row-tile pass; compressed stream includes
    // the index metadata (BZ bits per block).
    let wbytes_per_col_pass: f64 = match design.datapath {
        Datapath::Dense => (kb * design.dims.b as u64) as f64,
        Datapath::FixedDbb { b } => {
            kb as f64
                * (occupancy(design, stats) as f64 * b as f64 + design.dims.b as f64 / 8.0)
        }
        Datapath::Vdbb => {
            kb as f64 * (occupancy(design, stats) as f64 + design.dims.b as f64 / 8.0)
        }
        // surviving dense block values, plus the scheduler metadata at the
        // weight-SRAM rate and with **no per-element bitmask** (the
        // defining contrast with the (V)DBB streams): one u16 `col_idx`
        // per surviving block amortized over its B columns, one u32
        // `row_ptr` entry per block row amortized over all N columns.
        Datapath::Bsr => {
            kb as f64 * (design.dims.b as f64 + 2.0 / design.dims.b as f64)
                + 4.0 * (stats.kblocks() + 1) as f64 / stats.n as f64
        }
    };
    let weight_sram = (wbytes_per_col_pass * stats.n as f64 * row_tiles as f64) as u64;

    // activations re-stream once per column-tile pass; an encoded layer
    // fetches only the surviving values plus the per-block bitmask
    let act_edge = (mg as u64 * kb * design.dims.b as u64) * col_tiles;
    let act_raw = act_edge as f64 / im2col_magnification.max(1.0);
    // dense arrays have no A-side DBB decoder; neither does BSR (its
    // surviving blocks consume raw dense activation tiles)
    let act_encoded =
        act_encoded && !matches!(design.datapath, Datapath::Dense | Datapath::Bsr);
    let (act_sram, act_index) = if act_encoded {
        (
            (act_raw * (1.0 - act_sparsity.clamp(0.0, 1.0))) as u64,
            (act_raw / 8.0) as u64,
        )
    } else {
        (act_raw as u64, 0)
    };

    // outputs: requantized INT8 written back once (the INT32 accumulator
    // drain feeds the MCU requant path, which stores INT8 — §IV-D)
    let out_bytes = mg as u64 * stats.n as u64;

    let mux = match design.datapath {
        // no per-element operand selection on dense or BSR datapaths —
        // BSR skips in the scheduler, not in the MAC operand path
        Datapath::Dense | Datapath::Bsr => 0,
        _ => issued,
    };

    GemmTiming {
        events: EventCounts {
            cycles,
            macs_active: active.round() as u64,
            macs_gated: gated.round() as u64,
            macs_idle: idle,
            weight_sram_bytes: weight_sram,
            act_sram_bytes: act_sram,
            act_index_bytes: act_index,
            act_edge_bytes: act_edge,
            out_sram_bytes: out_bytes,
            mux_selects: mux,
            mcu_cycles: 0,
            epilogue_cycles: 0,
        },
        dense_macs: mg as u64 * stats.k as u64 * stats.n as u64,
    }
}

/// Exact-data timing: measures activation sparsity from the real operand
/// and weight statistics from the encoded matrix, then applies the closed
/// forms. This is what the coordinator's timing path uses per layer.
pub fn gemm_timing_exact(
    design: &Design,
    a: &TensorI8,
    w: &DbbMatrix,
    im2col_magnification: f64,
) -> GemmTiming {
    let mg = a.shape()[0];
    assert_eq!(a.shape()[1], w.k, "GEMM inner dim");
    let stats = WeightStats::of(w);
    let s = a.sparsity();
    gemm_timing_stats(design, mg, &stats, s, im2col_magnification)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Design;
    use crate::dbb::prune::prune_i8;
    use crate::util::Rng;

    fn vdbb() -> Design {
        Design::paper_optimal()
    }

    #[test]
    fn vdbb_throughput_approaches_nominal_over_density() {
        // big GEMM, per-density effective ops/cycle -> physical/density
        let d = vdbb();
        for bound in 1..=8usize {
            let stats = WeightStats::synthetic(4096, 512, 8, bound);
            let t = gemm_timing_stats(&d, 4096, &stats, 0.0, 1.0);
            let eff = t.effective_ops_per_cycle() / 2.0; // MACs/cycle
            let ideal = d.physical_macs() as f64 / stats.density();
            // within 15% of ideal (skew fill/drain + tiling overheads)
            assert!(
                eff > 0.85 * ideal && eff <= ideal,
                "bound={bound} eff={eff} ideal={ideal}"
            );
        }
    }

    #[test]
    fn fixed_dbb_dense_fallback_costs_more_cycles() {
        let d = Design::paper_fixed_dbb();
        let sparse = WeightStats::synthetic(1024, 256, 8, 4);
        let dense = WeightStats::synthetic(1024, 256, 8, 8);
        let ts = gemm_timing_stats(&d, 1024, &sparse, 0.0, 1.0);
        let td = gemm_timing_stats(&d, 1024, &dense, 0.0, 1.0);
        assert_eq!(occupancy(&d, &dense), 2);
        assert!(td.events.cycles > 18 * ts.events.cycles / 10); // ≈2x (minus skew/drain)
    }

    #[test]
    fn utilization_near_one_for_large_aligned_gemm() {
        let d = vdbb();
        let stats = WeightStats::synthetic(4096, 512, 8, 3);
        let t = gemm_timing_stats(&d, 4096, &stats, 0.5, 1.0);
        assert!(t.events.utilization() > 0.9, "util={}", t.events.utilization());
        // act sparsity round-trips through the counters (weight padding
        // slots also land in gated, so measured ≥ injected)
        assert!(t.events.act_sparsity() >= 0.49);
    }

    #[test]
    fn slot_conservation() {
        let d = vdbb();
        let stats = WeightStats::synthetic(100, 30, 8, 5);
        let t = gemm_timing_stats(&d, 77, &stats, 0.3, 1.0);
        assert_eq!(
            t.events.mac_slots(),
            d.physical_macs() as u64 * t.events.cycles
        );
    }

    #[test]
    fn weight_traffic_scales_with_bound() {
        let d = vdbb();
        let lo = WeightStats::synthetic(1024, 128, 8, 2);
        let hi = WeightStats::synthetic(1024, 128, 8, 8);
        let tl = gemm_timing_stats(&d, 512, &lo, 0.0, 1.0);
        let th = gemm_timing_stats(&d, 512, &hi, 0.0, 1.0);
        // 2-of-8 stream: (2 + 1) bytes/block vs (8 + 1): ratio 3x
        let ratio = th.events.weight_sram_bytes as f64 / tl.events.weight_sram_bytes as f64;
        assert!((ratio - 3.0).abs() < 0.05, "ratio={ratio}");
    }

    #[test]
    fn im2col_magnification_divides_act_sram_only() {
        let d = vdbb();
        let stats = WeightStats::synthetic(576, 64, 8, 4);
        let t1 = gemm_timing_stats(&d, 3136, &stats, 0.5, 1.0);
        let t3 = gemm_timing_stats(&d, 3136, &stats, 0.5, 3.0);
        assert_eq!(t1.events.act_edge_bytes, t3.events.act_edge_bytes);
        assert!(
            (t3.events.act_sram_bytes as f64 * 3.0 - t1.events.act_sram_bytes as f64).abs()
                < 4.0
        );
    }

    #[test]
    fn encoded_act_traffic_splits_values_and_index() {
        let d = vdbb();
        let stats = WeightStats::synthetic(512, 128, 8, 3);
        let raw = gemm_timing_stats(&d, 256, &stats, 0.5, 1.0);
        let enc = gemm_timing_stats_enc(&d, 256, &stats, 0.5, 1.0, true);
        // compression changes traffic, not the schedule or the gating
        assert_eq!(enc.events.cycles, raw.events.cycles);
        assert_eq!(enc.events.macs_active, raw.events.macs_active);
        assert_eq!(enc.events.macs_gated, raw.events.macs_gated);
        assert_eq!(enc.events.act_edge_bytes, raw.events.act_edge_bytes);
        assert_eq!(enc.events.weight_sram_bytes, raw.events.weight_sram_bytes);
        // value traffic shrinks by the zero fraction; the index stream is
        // 1 bit per logical element; raw layers carry no index bytes
        let r = raw.events.act_sram_bytes as f64;
        assert!((enc.events.act_sram_bytes as f64 - 0.5 * r).abs() <= 1.0);
        assert!((enc.events.act_index_bytes as f64 - r / 8.0).abs() <= 1.0);
        assert_eq!(raw.events.act_index_bytes, 0);
        // at 50% zeros the compressed total undercuts the raw fetch
        assert!(enc.events.act_sram_bytes + enc.events.act_index_bytes < raw.events.act_sram_bytes);
        // and on a dense operand encoding costs MORE (the Auto break-even)
        let dense = gemm_timing_stats_enc(&d, 256, &stats, 0.0, 1.0, true);
        let dense_raw = gemm_timing_stats(&d, 256, &stats, 0.0, 1.0);
        assert!(
            dense.events.act_sram_bytes + dense.events.act_index_bytes
                > dense_raw.events.act_sram_bytes
        );
        // a dense SA datapath has no DBB decoder: the flag is ignored there
        let sa = Design::baseline_sa();
        let sa_stats = WeightStats::synthetic(512, 128, 8, 8);
        let sa_enc = gemm_timing_stats_enc(&sa, 256, &sa_stats, 0.5, 1.0, true);
        let sa_raw = gemm_timing_stats(&sa, 256, &sa_stats, 0.5, 1.0);
        assert_eq!(sa_enc.events, sa_raw.events);
    }

    #[test]
    fn exact_matches_stats_with_measured_sparsity() {
        let mut rng = Rng::new(21);
        let a = TensorI8::rand_sparse(&[64, 64], 0.5, &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[64, 32], &mut rng), 8, 3);
        let w = DbbMatrix::compress_with_bound(&wd, 8, 3).unwrap();
        let d = vdbb();
        let exact = gemm_timing_exact(&d, &a, &w, 1.0);
        let stats = gemm_timing_stats(&d, 64, &WeightStats::of(&w), a.sparsity(), 1.0);
        assert_eq!(exact.events, stats.events);
    }

    #[test]
    fn bsr_throughput_scales_with_block_density() {
        // the scheduler skips absent blocks entirely, so effective
        // MACs/cycle -> physical / block-density (symmetric with VDBB,
        // but at block rather than element granularity)
        let d = Design::parse("4x8x8_2x4_BSR").unwrap();
        for bound in 1..=8usize {
            let stats = WeightStats::synthetic(4096, 512, 8, bound);
            let t = gemm_timing_stats(&d, 4096, &stats, 0.0, 1.0);
            let eff = t.effective_ops_per_cycle() / 2.0; // MACs/cycle
            let ideal = d.physical_macs() as f64 / stats.density();
            assert!(
                eff > 0.85 * ideal && eff <= ideal,
                "bound={bound} eff={eff} ideal={ideal}"
            );
        }
    }

    #[test]
    fn bsr_slot_conservation() {
        let d = Design::parse("4x8x8_2x4_BSR").unwrap();
        let stats = WeightStats::synthetic(100, 30, 8, 5);
        let t = gemm_timing_stats(&d, 77, &stats, 0.3, 1.0);
        assert_eq!(
            t.events.mac_slots(),
            d.physical_macs() as u64 * t.events.cycles
        );
    }

    #[test]
    fn bsr_weight_traffic_prices_index_without_bitmask() {
        let d = Design::parse("4x8x8_2x4_BSR").unwrap();
        let stats = WeightStats::synthetic(4096, 512, 8, 4); // 50% block density
        let t = gemm_timing_stats(&d, 4096, &stats, 0.0, 1.0);
        // exact pin: surviving dense values + u16 col_idx per block
        // (amortized over its 8 columns) + u32 row_ptr per block row
        // (amortized over all N columns), once per row-tile pass
        let kb = sched_blocks(&d, &stats) as f64;
        assert_eq!(kb, 256.0); // 512 kblocks x 4/8 survive
        let row_tiles = 4096f64 / 8.0; // mg / (A*M)
        let per_col = kb * (8.0 + 2.0 / 8.0) + 4.0 * (512.0 + 1.0) / 512.0;
        let expect = (per_col * 512.0 * row_tiles) as u64;
        assert_eq!(t.events.weight_sram_bytes, expect);
        // strictly cheaper than a (V)DBB-style per-element bitmask stream
        let with_bitmask = (kb * (8.0 + 8.0 / 8.0) * 512.0 * row_tiles) as u64;
        assert!(t.events.weight_sram_bytes < with_bitmask);
        // no operand muxes on the BSR datapath: skip happens in the
        // scheduler, not the MAC operand path
        assert_eq!(t.events.mux_selects, 0);
        // and no A-side DBB decoder: the encode flag is ignored
        let enc = gemm_timing_stats_enc(&d, 256, &stats, 0.5, 1.0, true);
        let raw = gemm_timing_stats(&d, 256, &stats, 0.5, 1.0);
        assert_eq!(enc.events, raw.events);
    }

    #[test]
    fn baseline_sa_insensitive_to_weight_sparsity_cycles() {
        let d = Design::baseline_sa();
        let lo = WeightStats::synthetic(512, 256, 8, 2);
        let hi = WeightStats::synthetic(512, 256, 8, 8);
        let tl = gemm_timing_stats(&d, 256, &lo, 0.0, 1.0);
        let th = gemm_timing_stats(&d, 256, &hi, 0.0, 1.0);
        assert_eq!(tl.events.cycles, th.events.cycles); // no speedup (Fig 12a)
        // but fewer active MACs (less switching -> Fig 12b energy slope)
        assert!(tl.events.macs_active < th.events.macs_active);
    }
}
