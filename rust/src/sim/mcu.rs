//! Cortex-M33 MCU model (paper §IV-D).
//!
//! The accelerator offloads ancillary operators (activation functions,
//! pooling, scaling/requantization, batch norm, casts) to small Arm
//! Cortex-M33 microcontrollers with 32-bit SIMD that packs four INT8 lanes
//! per instruction. The paper provisions 2 MCUs per 2 TOPS of peak
//! throughput (4 for the 4 TOPS design), each with a 64 KB program SRAM,
//! 0.008 mm² in 16 nm and 3.9 µW/MHz typical.

/// Ancillary operator classes the MCU executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum McuOp {
    /// ReLU (1 SIMD op per 4 elements).
    Relu,
    /// 2×2 max pooling (3 compares per output → ~1 SIMD op/elem).
    MaxPool2x2,
    /// Requantize INT32 accumulator → INT8 (scale+shift+saturate ≈ 2 ops/elem).
    Requant,
    /// Batch-norm fold (scale+bias, ≈ 2 ops/elem on INT8).
    BatchNorm,
    /// Elementwise residual add (1 SIMD op per 4 elements).
    Add,
}

impl McuOp {
    /// MCU cycles per *element* processed (INT8, using 4-lane SIMD).
    ///
    /// Requantization streams the INT32 accumulators with the per-layer
    /// power-of-two scale folded into the shift of a packing sequence
    /// (SSAT/USAT + pack), retiring one packed 4-lane word per instruction;
    /// a following ReLU folds into the *unsigned* saturate for free. This
    /// aggressive packing is what makes the paper's provisioning claim
    /// (§IV-D: 2 cores per 2 TOPS, 8 per 16 effective TOPS) self-consistent.
    pub fn cycles_per_elem(&self) -> f64 {
        match self {
            McuOp::Relu => 0.25,
            McuOp::MaxPool2x2 => 1.0,
            McuOp::Requant => 0.25,
            McuOp::BatchNorm => 0.5,
            McuOp::Add => 0.25,
        }
    }
}

/// MCU complex configuration.
#[derive(Debug, Clone, Copy)]
pub struct McuComplex {
    /// Number of M33 cores (paper: 2 per 2 TOPS peak).
    pub cores: usize,
}

impl McuComplex {
    /// Provision for a *peak effective* TOPS target. The paper's quoted
    /// points (§IV-D: 2 cores for 2 TOPS, 4 for 4 TOPS, 8 for 16 TOPS — the
    /// 16 being the effective throughput of a sparse design, Fig. 12) fit
    /// `⌈TOPS⌉` clamped to [2, 8]; we adopt exactly that.
    pub fn for_tops(tops: f64) -> McuComplex {
        McuComplex {
            cores: (tops.ceil() as usize).clamp(2, 8),
        }
    }

    /// Cycles (at the accelerator clock) for the cores to process `elems`
    /// elements of `op`, split across cores.
    pub fn cycles(&self, op: McuOp, elems: u64) -> u64 {
        let per_core = elems as f64 * op.cycles_per_elem() / self.cores as f64;
        per_core.ceil() as u64
    }

    /// Total MCU cycles for a conv layer's post-processing: requantization
    /// over the output feature map, with a following ReLU folded into the
    /// unsigned saturate (no extra cycles — see [`McuOp::cycles_per_elem`]).
    pub fn conv_post_cycles(&self, out_elems: u64, _relu: bool) -> u64 {
        self.cycles(McuOp::Requant, out_elems)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn provisioning_follows_paper() {
        // §IV-D quoted points: 2 per 2 TOPS, 4 per 4 TOPS, 8 per 16 TOPS
        assert_eq!(McuComplex::for_tops(2.0).cores, 2);
        assert_eq!(McuComplex::for_tops(4.0).cores, 4);
        assert_eq!(McuComplex::for_tops(16.0).cores, 8);
        assert_eq!(McuComplex::for_tops(1.0).cores, 2); // floor of 2
        assert_eq!(McuComplex::for_tops(32.8).cores, 8); // cap of 8
    }

    #[test]
    fn simd_packing_reduces_relu_cost() {
        let m = McuComplex { cores: 4 };
        // 1M elems ReLU on 4 cores at 0.25 cyc/elem = 62.5k cycles
        assert_eq!(m.cycles(McuOp::Relu, 1_000_000), 62_500);
    }

    #[test]
    fn relu_folds_into_requant_saturate() {
        let m = McuComplex { cores: 4 };
        assert_eq!(
            m.conv_post_cycles(100_000, true),
            m.conv_post_cycles(100_000, false)
        );
        assert_eq!(m.conv_post_cycles(100_000, true), m.cycles(McuOp::Requant, 100_000));
    }
}
