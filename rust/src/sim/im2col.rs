//! Hardware IM2COL unit model (paper §IV-C, Fig. 8).
//!
//! The unit sits between the activation SRAM and the datapath and acts as a
//! *read-bandwidth magnifier*: it caches a small tile of the feature map
//! (6×4 pixels in the paper) in buffer registers and regenerates the
//! duplicated IM2COL pixels from the buffer instead of re-reading them from
//! SRAM. For a 3×3 stride-1 kernel the paper's unit refills 6×4 inputs
//! every 9 cycles while producing two 4-wide outputs per cycle — a 3×
//! average SRAM-read reduction.
//!
//! This model derives the achievable magnification for any conv shape from
//! the buffer geometry, and also exposes a functional row-generation path
//! used in tests to prove the buffered outputs equal the software IM2COL.
//! That path is the *same generator* the fused software engine runs on
//! ([`crate::gemm::fused::patch_row_into`]); the two formulas that quantify
//! the expansion — [`crate::gemm::conv::im2col_expansion`] (total operand
//! blowup of the materializing lowering) and [`Im2colUnit::magnification`]
//! (the fraction of that blowup the row buffer regenerates) — are
//! cross-tested in `rust/tests/fused_conv.rs`.

use crate::gemm::conv::{im2col_expansion, ConvShape};

/// Buffer geometry of the hardware unit.
#[derive(Debug, Clone, Copy)]
pub struct Im2colUnit {
    /// Buffered rows of the feature-map tile (paper: 6).
    pub buf_rows: usize,
    /// Buffered columns per row (paper: 4... per bank; two banks of 6×2).
    pub buf_cols: usize,
}

impl Default for Im2colUnit {
    fn default() -> Self {
        Im2colUnit {
            buf_rows: 6,
            buf_cols: 4,
        }
    }
}

impl Im2colUnit {
    /// SRAM-read magnification factor for a conv shape: how many bytes of
    /// IM2COL operand each SRAM byte expands to.
    ///
    /// Each feature-map pixel is needed by up to `ceil(kh/stride)` output
    /// rows and `ceil(kw/stride)` output columns; the unit can exploit the
    /// vertical reuse up to its buffered-row capacity (it holds
    /// `buf_rows − kh + 1 + (kh−1) = buf_rows` rows, serving
    /// `buf_rows − kh + 1` output rows per refill) and the full horizontal
    /// reuse within a row. The paper quotes the *net* effect for 3×3 s=1 as
    /// 3× — vertical reuse only (horizontal duplication is regenerated from
    /// the row buffer as part of the same read).
    ///
    /// The unit can never save more traffic than the duplication actually
    /// present in the finite operand, so the result is additionally capped
    /// by [`im2col_expansion`] (clamped at 1 — subsampling convs with
    /// `stride > kh` have expansion < 1 and simply bypass the unit). This
    /// keeps `expansion.max(1) ≥ magnification` an invariant for *every*
    /// shape, including tiny edge-dominated maps where the interior formula
    /// would overestimate.
    pub fn magnification(&self, s: &ConvShape) -> f64 {
        if s.kh <= 1 || s.stride >= s.kh {
            return 1.0; // 1×1 kernels / stride ≥ kernel: no duplication
        }
        if s.kh > self.buf_rows {
            return 1.0; // window taller than the buffer (e.g. 7×7): no reuse
        }
        // vertical reuse the buffer can capture: serves buf_rows−kh+1
        // output rows per refill
        let vertical =
            (s.kh as f64 / s.stride as f64).min((self.buf_rows - s.kh + 1) as f64);
        vertical.max(1.0).min(im2col_expansion(s).max(1.0))
    }

    /// Cycles per refill burst and bytes per refill, for the bandwidth
    /// model: the paper's unit reads `buf_rows×buf_cols` bytes per
    /// `(kh·kw)` cycles of output generation.
    pub fn refill_bytes(&self) -> usize {
        self.buf_rows * self.buf_cols
    }

    /// Functional check helper: generate the IM2COL rows for one output
    /// pixel from a buffered window — proves the buffer contents suffice
    /// (no SRAM re-read) for all `kh·kw` taps of outputs inside the tile.
    /// Returns the flattened `[kh·kw·c]` operand row.
    ///
    /// Delegates to the shared row generator
    /// [`crate::gemm::fused::patch_row_into`] — the functional unit and the
    /// fused software engine are one code path by construction.
    pub fn generate_row(
        &self,
        x: &crate::tensor::TensorI8,
        s: &ConvShape,
        oy: usize,
        ox: usize,
    ) -> Vec<i8> {
        let mut row = vec![0i8; s.gemm_k()];
        crate::gemm::fused::patch_row_into(x.data(), s, oy, ox, &mut row);
        row
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::conv::im2col;
    use crate::tensor::TensorI8;
    use crate::util::Rng;

    fn shape(kh: usize, stride: usize) -> ConvShape {
        ConvShape {
            h: 16,
            w: 16,
            c: 4,
            kh,
            kw: kh,
            oc: 8,
            stride,
            pad: kh / 2,
        }
    }

    #[test]
    fn paper_3x3_gives_3x() {
        let u = Im2colUnit::default();
        assert!((u.magnification(&shape(3, 1)) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn pointwise_gives_1x() {
        let u = Im2colUnit::default();
        assert_eq!(u.magnification(&shape(1, 1)), 1.0);
    }

    #[test]
    fn five_by_five_capped_by_buffer() {
        let u = Im2colUnit::default();
        // 5x5 s1: vertical reuse 5, but buffer serves 6-5+1 = 2 rows/refill
        assert!((u.magnification(&shape(5, 1)) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stride2_3x3_less_reuse() {
        let u = Im2colUnit::default();
        let m = u.magnification(&shape(3, 2));
        assert!((m - 1.5).abs() < 1e-12, "m={m}");
    }

    #[test]
    fn tiny_map_capped_by_actual_expansion() {
        // 5×5 input, 5×5 kernel, no pad: a single output pixel — the operand
        // has no duplication at all (expansion exactly 1), so the buffer's
        // nominal 2× vertical reuse cannot materialize.
        let u = Im2colUnit::default();
        let s = ConvShape { h: 5, w: 5, c: 3, kh: 5, kw: 5, oc: 2, stride: 1, pad: 0 };
        assert!((im2col_expansion(&s) - 1.0).abs() < 1e-12);
        assert_eq!(u.magnification(&s), 1.0);
    }

    #[test]
    fn generated_rows_match_software_im2col() {
        let mut rng = Rng::new(31);
        let s = shape(3, 1);
        let x = TensorI8::rand(&[s.h, s.w, s.c], &mut rng);
        let sw = im2col(&x, &s);
        let u = Im2colUnit::default();
        for oy in [0usize, 3, 15] {
            for ox in [0usize, 7, 15] {
                let row = u.generate_row(&x, &s, oy, ox);
                let want: Vec<i8> =
                    (0..s.gemm_k()).map(|k| sw.at(&[oy * s.ow() + ox, k])).collect();
                assert_eq!(row, want, "oy={oy} ox={ox}");
            }
        }
    }
}
