//! Local SRAM model (paper §IV-B): 512 KB weight buffer (WB) + 2 MB
//! activation buffer (AB), double-buffered, software managed.
//!
//! The model tracks capacity feasibility (does a layer's working set fit,
//! or does it need K/N-striping with DRAM spill — the paper sizes the
//! buffers so ResNet-50 layers fit) and turns byte-traffic counts from the
//! timing engine into access events for the power model.

/// SRAM instance parameters.
#[derive(Debug, Clone, Copy)]
pub struct Sram {
    /// Capacity in bytes.
    pub bytes: usize,
    /// Access word width in bytes (row of the bank mux).
    pub word_bytes: usize,
    /// Double buffered (halves usable capacity per phase, allows overlap
    /// of DMA fill with compute — paper §IV-B).
    pub double_buffered: bool,
}

impl Sram {
    /// The paper's 512 KB weight buffer.
    pub fn weight_buffer() -> Sram {
        Sram {
            bytes: 512 << 10,
            word_bytes: 16,
            double_buffered: true,
        }
    }

    /// The paper's 2 MB activation buffer.
    pub fn activation_buffer() -> Sram {
        Sram {
            bytes: 2 << 20,
            word_bytes: 16,
            double_buffered: true,
        }
    }

    /// Usable bytes per phase.
    pub fn usable(&self) -> usize {
        if self.double_buffered {
            self.bytes / 2
        } else {
            self.bytes
        }
    }

    /// Whether a working set fits in one phase.
    pub fn fits(&self, working_set: usize) -> bool {
        working_set <= self.usable()
    }

    /// Number of word accesses for a byte-traffic count (reads or writes).
    pub fn accesses(&self, traffic_bytes: u64) -> u64 {
        traffic_bytes.div_ceil(self.word_bytes as u64)
    }
}

/// Double-buffer phase tracker: models ping-pong between compute and DMA.
#[derive(Debug, Default)]
pub struct DoubleBuffer {
    phase: bool,
    /// Cycles the datapath stalled waiting for a DMA fill to finish.
    pub stall_cycles: u64,
}

impl DoubleBuffer {
    /// Advance one phase: compute consumed `compute_cycles` while the next
    /// fill needs `fill_cycles`; any excess fill time stalls the array.
    pub fn advance(&mut self, compute_cycles: u64, fill_cycles: u64) {
        self.phase = !self.phase;
        self.stall_cycles += fill_cycles.saturating_sub(compute_cycles);
    }

    /// Current phase id (0/1).
    pub fn phase(&self) -> usize {
        self.phase as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_capacities() {
        assert_eq!(Sram::weight_buffer().bytes, 524_288);
        assert_eq!(Sram::activation_buffer().bytes, 2_097_152);
    }

    #[test]
    fn double_buffering_halves_capacity() {
        let wb = Sram::weight_buffer();
        assert_eq!(wb.usable(), 262_144);
        assert!(wb.fits(200_000));
        assert!(!wb.fits(300_000));
    }

    #[test]
    fn word_access_counting() {
        let wb = Sram::weight_buffer();
        assert_eq!(wb.accesses(0), 0);
        assert_eq!(wb.accesses(1), 1);
        assert_eq!(wb.accesses(16), 1);
        assert_eq!(wb.accesses(17), 2);
    }

    #[test]
    fn double_buffer_stalls_when_fill_slower() {
        let mut db = DoubleBuffer::default();
        db.advance(100, 60); // fill hidden
        assert_eq!(db.stall_cycles, 0);
        db.advance(100, 150); // 50 cycle bubble
        assert_eq!(db.stall_cycles, 50);
        assert_eq!(db.phase(), 0);
    }
}
