//! Architecture simulator for the SA / STA / STA-DBB / STA-VDBB datapath
//! arrays (paper §IV), with two engines cross-validated against each other:
//!
//! * [`detailed`] — a per-MAC, per-cycle functional simulator. Slow, but
//!   bit-exact against the golden GEMM and used as ground truth in tests.
//! * [`analytic`] — closed-form cycle/event model (DBB schedules are fully
//!   deterministic, paper §V-C), fast enough to sweep whole CNNs across the
//!   design space. Property tests assert it agrees with [`detailed`].
//!
//! [`accel`] composes either engine with the SRAM ([`sram`]), hardware
//! IM2COL unit ([`im2col`]) and MCU ([`mcu`]) models into a whole-network
//! timing/energy event stream consumed by `crate::power`.

pub mod accel;
pub mod analytic;
pub mod detailed;
pub mod im2col;
pub mod mcu;
pub mod sram;

/// Switching/activity event counters produced by a simulation and consumed
/// by the power model — the moral equivalent of the paper's VCD traces.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EventCounts {
    /// Datapath cycles (array busy).
    pub cycles: u64,
    /// MAC operations issued to a physical MAC with a non-zero activation
    /// (full switching).
    pub macs_active: u64,
    /// MAC slots where the activation operand was zero: clock-gated on
    /// gating-capable datapaths (SA/VDBB), data-gated (reduced switching,
    /// registers still clocked) on wide-DP datapaths.
    pub macs_gated: u64,
    /// Idle MAC slots (array under-utilization: skew fill/drain, partial
    /// tiles, dense fallback stalls).
    pub macs_idle: u64,
    /// Weight bytes read from the weight SRAM (compressed stream for
    /// DBB/VDBB, including the index metadata bytes).
    pub weight_sram_bytes: u64,
    /// Activation bytes read from the activation SRAM (after IM2COL
    /// magnification when the unit is present — i.e. actual SRAM traffic).
    /// For a layer whose activations stream DBB-encoded this is the
    /// compressed *value* traffic (zeros are never fetched); the bitmask
    /// metadata is counted separately in [`Self::act_index_bytes`].
    pub act_sram_bytes: u64,
    /// A-side DBB index (bitmask) bytes read alongside a compressed
    /// activation stream — the metadata overhead of activation-side DBB
    /// encoding (1 bit per logical element). 0 for layers whose
    /// activations stream raw.
    pub act_index_bytes: u64,
    /// Activation bytes consumed at the array edge (pre-magnifier demand).
    pub act_edge_bytes: u64,
    /// Output bytes written back to SRAM (INT32 accumulators, requantized
    /// to INT8 by the MCU path).
    pub out_sram_bytes: u64,
    /// Mux select toggles (one per MAC issue on sparse datapaths).
    pub mux_selects: u64,
    /// MCU cycles spent on ancillary ops (ReLU/pool/requant), overlappable.
    pub mcu_cycles: u64,
    /// Ancillary-op cycles for layers whose requant/ReLU/pool epilogue runs
    /// **fused in the array's output walk** instead of on the MCU (the
    /// engine's `execute_fused` style). Overlappable like
    /// [`Self::mcu_cycles`], but counted separately so the Fig-11 MCU
    /// normalization never mixes the two execution styles. Exactly one of
    /// `mcu_cycles` / `epilogue_cycles` is non-zero for a given layer.
    pub epilogue_cycles: u64,
}

impl EventCounts {
    /// Accumulate another counter set (e.g. across layers).
    pub fn add(&mut self, o: &EventCounts) {
        self.cycles += o.cycles;
        self.macs_active += o.macs_active;
        self.macs_gated += o.macs_gated;
        self.macs_idle += o.macs_idle;
        self.weight_sram_bytes += o.weight_sram_bytes;
        self.act_sram_bytes += o.act_sram_bytes;
        self.act_index_bytes += o.act_index_bytes;
        self.act_edge_bytes += o.act_edge_bytes;
        self.out_sram_bytes += o.out_sram_bytes;
        self.mux_selects += o.mux_selects;
        self.mcu_cycles += o.mcu_cycles;
        self.epilogue_cycles += o.epilogue_cycles;
    }

    /// Total MAC issue slots (active + gated + idle) — equals
    /// `physical_macs × cycles` for a well-formed simulation.
    pub fn mac_slots(&self) -> u64 {
        self.macs_active + self.macs_gated + self.macs_idle
    }

    /// Datapath utilization: fraction of MAC slots doing useful (issued)
    /// work — gated slots count as *issued* (they hold real zero-operand
    /// work the schedule assigned), idle slots do not.
    pub fn utilization(&self) -> f64 {
        let slots = self.mac_slots();
        if slots == 0 {
            return 0.0;
        }
        (self.macs_active + self.macs_gated) as f64 / slots as f64
    }

    /// Measured activation sparsity over issued MACs.
    pub fn act_sparsity(&self) -> f64 {
        let issued = self.macs_active + self.macs_gated;
        if issued == 0 {
            return 0.0;
        }
        self.macs_gated as f64 / issued as f64
    }
}

/// Result of simulating one GEMM on an array.
#[derive(Debug, Clone, Default)]
pub struct GemmTiming {
    /// Event counters.
    pub events: EventCounts,
    /// Dense-equivalent MACs of the computed GEMM (M·K·N).
    pub dense_macs: u64,
}

impl GemmTiming {
    /// Effective ops/cycle = 2·dense MACs / cycles.
    pub fn effective_ops_per_cycle(&self) -> f64 {
        if self.events.cycles == 0 {
            return 0.0;
        }
        2.0 * self.dense_macs as f64 / self.events.cycles as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_add_accumulates() {
        let mut a = EventCounts {
            cycles: 10,
            macs_active: 5,
            ..Default::default()
        };
        let b = EventCounts {
            cycles: 3,
            macs_gated: 2,
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.cycles, 13);
        assert_eq!(a.macs_active, 5);
        assert_eq!(a.macs_gated, 2);
        assert_eq!(a.mac_slots(), 7);
    }

    #[test]
    fn utilization_and_sparsity() {
        let e = EventCounts {
            macs_active: 60,
            macs_gated: 20,
            macs_idle: 20,
            ..Default::default()
        };
        assert!((e.utilization() - 0.8).abs() < 1e-12);
        assert!((e.act_sparsity() - 0.25).abs() < 1e-12);
    }
}
