//! Whole-accelerator timing: run a CNN layer table (`crate::models`)
//! through the analytic engine plus the IM2COL-unit, SRAM and MCU models,
//! producing per-layer and whole-network event counts for the power model
//! (paper Figs 9, 11, 12).
//!
//! Activation sparsity is *measured*, not assumed: [`profile_model`] runs a
//! sampled functional INT8 inference (synthetic DBB-pruned weights, random
//! input image, per-layer requantization + ReLU) and propagates the sampled
//! *feature map* layer to layer — conv layers convolve a real sub-window of
//! that map through the fused streaming engine
//! ([`crate::gemm::fused::conv2d_i8`] / [`crate::gemm::fused::conv2d_dbb_i8`]),
//! so the operand the sparsity is measured on has genuine IM2COL structure
//! (duplicated pixels, padding zeros) instead of being an i.i.d. random
//! matrix — reproducing the layer-by-layer sparsity variation the paper
//! annotates above the Fig. 11 bars. For the sensitivity sweeps (Fig. 12's
//! 50%/80% curves) use [`profile_model_fixed_act`].
//!
//! The sampled functional pass itself lives in [`crate::engine`]
//! (prepare-once/execute-many): [`profile_model`] lowers the model into a
//! [`crate::engine::PreparedModel`] — weights encoded and CSC-packed
//! exactly once — and replays one seeded execute over the packed operands.
//!
//! The measured [`LayerProfile::act_sparsity`] is the **one sparsity
//! source** for both uses of activation sparsity in this codebase: the
//! analytic model prices the datapath's A-side MAC gating with it
//! (`macs_gated` in [`crate::sim::analytic::gemm_timing_stats`]'s event
//! counts), and the software
//! kernels' [`crate::gemm::ActPolicy::Auto`] (and its two-way predecessor
//! [`crate::gemm::ZeroGate::Auto`]) consults the same per-layer value to
//! decide where the zero-skip / A-DBB-encode passes pay. Layers the engine
//! resolves to *encode* carry [`LayerProfile::act_encoded`], and the
//! timing model then prices their activation SRAM traffic as the
//! compressed DBB stream — surviving values plus index bytes
//! ([`crate::sim::analytic::gemm_timing_stats_enc`]) — so the twin's
//! energy/latency estimates distinguish "skipped the multiply" (gated
//! MACs) from "never fetched the operand" (compressed A traffic).

use super::analytic::{gemm_timing_stats_enc, WeightStats};
use super::im2col::Im2colUnit;
use super::mcu::McuComplex;
use super::EventCounts;
use crate::arch::Design;
use crate::models::{LayerKind, Model};
use crate::util::par::map_indexed;
use crate::util::Parallelism;

/// Everything the timing/power model needs to know about one layer.
#[derive(Debug, Clone)]
pub struct LayerProfile {
    /// Layer name.
    pub name: String,
    /// GEMM rows (output pixels × batch).
    pub m: usize,
    /// Weight statistics (synthetic-exact for magnitude-pruned weights).
    /// For a [`crate::gemm::WeightFormat::Bsr`] layer `bound/bz` is the
    /// *block* density (see [`WeightStats::of_bsr`]).
    pub weights: WeightStats,
    /// How this layer's weights are packed — decides which datapath
    /// pricing the timing and buffer models apply (DBB bitmask stream vs
    /// BSR `row_ptr`/`col_idx` walk vs raw dense).
    pub format: crate::gemm::WeightFormat,
    /// Zero fraction of the layer's *raw input* operand — the feature map
    /// (or FC matrix) as fed to the layer, **before** IM2COL expansion.
    /// That is exactly what [`crate::engine::PreparedModel::profile`]
    /// records (the zero fraction of the fitted input it convolves; pinned
    /// by `recorded_act_sparsity_is_raw_input_zero_fraction`). The timing
    /// model applies it as the A-operand zero fraction of the GEMM — a
    /// slight *under*-estimate for padded convolutions, since IM2COL
    /// duplication preserves the zero fraction and padding only adds
    /// zeros. The software kernels' [`crate::gemm::ZeroGate::Auto`]
    /// consults the same measured value, so the priced datapath gate and
    /// the software gate share one sparsity source.
    pub act_sparsity: f64,
    /// Whether this layer's activation operand streams **DBB-encoded**
    /// (the engine's resolved [`crate::gemm::ActPolicy::Encode`] decision
    /// for the layer — set by `PreparedModel::profiles`, `false` for the
    /// assumed-sparsity profiles). The timing model then prices the
    /// compressed A stream (value bytes shrunk by `act_sparsity`, plus
    /// 1 bit/element of index metadata) instead of the raw fetch.
    pub act_encoded: bool,
    /// IM2COL duplication this layer offers (1.0 for FC/1×1).
    pub im2col_magnification: f64,
    /// Raw input bytes (the feature map / FC input vector) — the AB
    /// working set when the IM2COL unit regenerates the expansion.
    pub raw_act_bytes: u64,
    /// Output elements (for MCU post-processing).
    pub out_elems: u64,
    /// Followed by ReLU?
    pub relu: bool,
    /// This layer's requant/ReLU/pool epilogue runs **fused inside the
    /// array's output walk** (the engine's `execute_fused` style) instead
    /// of as MCU post-processing. [`layer_timing`] then prices the
    /// post-processing work as [`EventCounts::epilogue_cycles`] —
    /// overlapped with the array like the MCU column, but accounted
    /// separately so Fig-11's MCU normalization stays honest. Set by
    /// `PreparedModel::profiles` ([`crate::engine::PreparedModel::set_fused_epilogue`]);
    /// `false` for the assumed-sparsity profiles.
    pub fused_epilogue: bool,
}

/// Per-layer timing result.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    /// Layer name.
    pub name: String,
    /// Event counters (including MCU cycles).
    pub events: EventCounts,
    /// Dense-equivalent MACs.
    pub dense_macs: u64,
    /// Input activation sparsity used.
    pub act_sparsity: f64,
}

/// Whole-network timing result.
#[derive(Debug, Clone)]
pub struct NetworkTiming {
    /// Per-layer breakdown.
    pub layers: Vec<LayerTiming>,
    /// Aggregate events.
    pub total: EventCounts,
    /// Total dense-equivalent MACs.
    pub dense_macs: u64,
}

impl NetworkTiming {
    /// Wall-clock seconds at the design's frequency (array, MCU, and the
    /// fused epilogue all overlap; the slowest of the three gates each
    /// layer).
    pub fn seconds(&self, design: &Design) -> f64 {
        let cycles: u64 = self
            .layers
            .iter()
            .map(|l| l.events.cycles.max(l.events.mcu_cycles).max(l.events.epilogue_cycles))
            .sum();
        cycles as f64 / design.tech.freq_hz()
    }

    /// Effective TOPS over the network (2 × dense MACs / time).
    pub fn effective_tops(&self, design: &Design) -> f64 {
        2.0 * self.dense_macs as f64 / self.seconds(design) / 1e12
    }
}

/// Functional profile of a model: synthesize DBB-pruned INT8 weights,
/// run a sampled forward pass, measure per-layer activation sparsity.
///
/// `nnz` is the model-wide DBB target (paper Table I: e.g. 3/8 for
/// ResNet-50); `seed` fixes the synthetic weights and input. Conv layers
/// convolve a real sub-window of the propagated feature map through the
/// fused streaming engine (no IM2COL operand is materialized); FC layers
/// run on the tiled parallel engine. Both are bit-exact with their serial
/// paths at any worker-pool width, so the measured sparsities are
/// reproducible.
///
/// Since the prepared-model engine landed this is a thin wrapper over
/// [`crate::engine::PreparedModel`]: prepare (the one-time weight
/// encode/pack) + profile (the sampled execute). Callers that profile or
/// serve the same model repeatedly should hold the `PreparedModel`
/// themselves and amortize the prepare across calls.
pub fn profile_model(model: &Model, nnz: usize, bz: usize, seed: u64) -> Vec<LayerProfile> {
    profile_model_with(model, nnz, bz, seed, Parallelism::auto())
}

/// [`profile_model`] with an explicit worker-pool width for the sampled
/// functional GEMMs (`Parallelism::serial()` = the original single-threaded
/// path; results are bit-identical either way).
pub fn profile_model_with(
    model: &Model,
    nnz: usize,
    bz: usize,
    seed: u64,
    par: Parallelism,
) -> Vec<LayerProfile> {
    let mut pm = crate::engine::PreparedModel::prepare(model, nnz, bz, seed, par);
    pm.profile(par)
}

/// Profile with a *fixed* activation sparsity everywhere (paper Fig. 12's
/// "50% and 80% activation sparsity" sweeps).
pub fn profile_model_fixed_act(
    model: &Model,
    nnz: usize,
    bz: usize,
    act_sparsity: f64,
) -> Vec<LayerProfile> {
    let nlayers = model.layers.len();
    model
        .layers
        .iter()
        .enumerate()
        .map(|(li, l)| {
            let (m, k, n) = l.gemm_dims();
            let bound = l.dbb_bound(nnz, bz);
            let (im2c, raw) = match l.kind {
                LayerKind::Conv(s) | LayerKind::DepthwiseConv(s) => (
                    Im2colUnit::default().magnification(&s),
                    (s.h * s.w * s.c) as u64,
                ),
                LayerKind::Fc(i, _) => (1.0, i as u64),
            };
            LayerProfile {
                name: l.name.clone(),
                m,
                weights: WeightStats::synthetic(k, n, bz, bound),
                format: crate::gemm::WeightFormat::Dbb,
                act_sparsity,
                act_encoded: false,
                im2col_magnification: im2c,
                raw_act_bytes: raw,
                out_elems: (m * n) as u64,
                relu: li + 1 < nlayers,
                fused_epilogue: false,
            }
        })
        .collect()
}

/// The paper's power-analysis workload (§V-C): "for power consumption
/// analysis, we capture VCD traces in RTL simulation from representative
/// layers of ResNet50". Table IV's own numbers identify those as the 3×3
/// layers (ASRAM power is exactly 3× with the IM2COL unit disabled — the
/// full 3×3 magnification). This selects the 3×3 conv layers of a model,
/// with a fixed activation sparsity.
pub fn profile_model_repr(
    model: &Model,
    nnz: usize,
    bz: usize,
    act_sparsity: f64,
) -> Vec<LayerProfile> {
    profile_model_fixed_act(model, nnz, bz, act_sparsity)
        .into_iter()
        .zip(&model.layers)
        .filter(|(_, l)| matches!(l.kind, LayerKind::Conv(s) if s.kh == 3))
        .map(|(p, _)| p)
        .collect()
}

/// INT32 accumulators → INT8 with a per-layer power-of-two scale, then ReLU.
/// Relocated to [`crate::gemm::epilogue`] — its kernel-side home now that
/// the GEMM stack fuses the requantize into the output walk — and
/// re-exported here to preserve the historical import path (same function,
/// same bits).
pub use crate::gemm::epilogue::requant_relu;

/// Per-layer buffer feasibility (paper §IV-B: the 512 KB WB / 2 MB AB are
/// double-buffered and software managed). The schedule streams weights one
/// output-channel *stripe* at a time (a column-tile group of the array),
/// so the WB working set is per stripe; layers whose full compressed
/// weights exceed the WB simply take multiple DMA phases — `wb_phases`
/// counts them. Activation working set is the raw input feature map (the
/// IM2COL unit regenerates the expansion, §IV-C).
#[derive(Debug, Clone)]
pub struct BufferFeasibility {
    /// Layer name.
    pub name: String,
    /// Compressed weight bytes (whole layer).
    pub weight_bytes: usize,
    /// Weight bytes of one column stripe (the per-phase working set).
    pub stripe_bytes: usize,
    /// DMA phases needed to stream all weights through the WB.
    pub wb_phases: usize,
    /// Input activation working set (feature map / FC vector): raw bytes,
    /// or the compressed value+index stream for an A-DBB-encoded layer.
    pub act_bytes: usize,
    /// One weight stripe fits the (double-buffered) weight buffer.
    pub stripe_fits: bool,
    /// Activations fit the (double-buffered) activation buffer.
    pub acts_fit: bool,
}

/// Check every layer of a profiled model against the paper's buffers;
/// `stripe_cols` is the array's column-tile width (C·N of the design).
pub fn buffer_feasibility(profiles: &[LayerProfile], stripe_cols: usize) -> Vec<BufferFeasibility> {
    let wb = super::sram::Sram::weight_buffer();
    let ab = super::sram::Sram::activation_buffer();
    profiles
        .iter()
        .map(|p| {
            let kb = p.weights.kblocks();
            let (weight_bytes, stripe_bytes) = if matches!(p.format, crate::gemm::WeightFormat::Bsr)
                && p.weights.bound < p.weights.bz
            {
                // BSR: surviving dense block values + the row_ptr/col_idx
                // walk — a BSR layer carries **no** DBB per-element bitmask
                // byte (the historical overcount this branch removes).
                // Uniform matched-sparsity budgets: ceil(kb·bound/bz)
                // surviving blocks per block-column.
                let bz = p.weights.bz;
                let surv = (kb * p.weights.bound).div_ceil(bz).max(1);
                let nbc = p.weights.n.div_ceil(bz);
                let row_ptr = 4 * (kb + 1);
                let wbytes = surv * bz * p.weights.n + row_ptr + 2 * surv * nbc;
                let scols = stripe_cols.min(p.weights.n);
                let sbc = scols.div_ceil(bz).max(1);
                let sbytes = surv * bz * scols + row_ptr + 2 * surv * sbc;
                (wbytes, sbytes)
            } else {
                // compressed stream: bound bytes + BZ/8 index bytes per
                // block. Dense-fallback layers (bound == bz) stream the raw
                // weights — there is nothing for a bitmask to select, so
                // they carry no index bytes (historically overcounted
                // ~12.5%). A dense-fallback BSR layer is the same raw
                // stream (every block survives).
                let per_col = if p.weights.bound >= p.weights.bz {
                    kb * p.weights.bz
                } else {
                    kb * (p.weights.bound + p.weights.bz.div_ceil(8))
                };
                (per_col * p.weights.n, per_col * stripe_cols.min(p.weights.n))
            };
            // input map working set: raw (the IM2COL unit regenerates the
            // expansion), or the compressed value+index stream when the
            // layer's activations are DBB-encoded
            let raw = p.raw_act_bytes as usize;
            let act_bytes = if p.act_encoded {
                (raw as f64 * (1.0 - p.act_sparsity.clamp(0.0, 1.0))).ceil() as usize
                    + raw.div_ceil(8)
            } else {
                raw
            };
            BufferFeasibility {
                name: p.name.clone(),
                weight_bytes,
                stripe_bytes,
                wb_phases: weight_bytes.div_ceil(wb.usable()),
                act_bytes,
                stripe_fits: wb.fits(stripe_bytes),
                acts_fit: ab.fits(act_bytes),
            }
        })
        .collect()
}

/// Timing of one profiled layer on a design.
pub fn layer_timing(design: &Design, p: &LayerProfile, mcu: &McuComplex) -> LayerTiming {
    let mag = if design.im2col {
        p.im2col_magnification
    } else {
        1.0
    };
    let t = gemm_timing_stats_enc(design, p.m, &p.weights, p.act_sparsity, mag, p.act_encoded);
    let mut events = t.events;
    // the requant/ReLU(/pool) post-processing: MCU column for the staged
    // chain, the array-overlapped epilogue counter when the layer executes
    // with the epilogue fused into the GEMM output walk
    let post = mcu.conv_post_cycles(p.out_elems, p.relu);
    if p.fused_epilogue {
        events.epilogue_cycles = post;
    } else {
        events.mcu_cycles = post;
    }
    LayerTiming {
        name: p.name.clone(),
        events,
        dense_macs: t.dense_macs,
        act_sparsity: p.act_sparsity,
    }
}

/// Whole-network timing on a design (serial; see [`network_timing_with`]
/// for the parallel variant — callers that already parallelize across
/// designs, like the Fig-10 sweep, should keep this one to avoid
/// oversubscription).
pub fn network_timing(design: &Design, profiles: &[LayerProfile]) -> NetworkTiming {
    network_timing_with(design, profiles, Parallelism::serial())
}

/// Whole-network timing with the per-layer analytic models evaluated on the
/// worker pool. `layer_timing` is pure, so results are identical to the
/// serial path for any thread count. Note: pool setup costs tens of µs per
/// call — worth it for ResNet-50-class layer counts, not for 5-layer
/// models, which is why latency-sensitive callers (the serving twin)
/// default to `Parallelism::serial()`.
pub fn network_timing_with(
    design: &Design,
    profiles: &[LayerProfile],
    par: Parallelism,
) -> NetworkTiming {
    let mcu = McuComplex::for_tops(design.peak_effective_tops());
    let layers: Vec<LayerTiming> =
        map_indexed(profiles.len(), par, |i| layer_timing(design, &profiles[i], &mcu));
    let mut total = EventCounts::default();
    for l in &layers {
        total.add(&l.events);
    }
    let dense_macs = layers.iter().map(|l| l.dense_macs).sum();
    NetworkTiming {
        layers,
        total,
        dense_macs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn resnet_profile_measures_plausible_act_sparsity() {
        let m = models::resnet50();
        let p = profile_model(&m, 3, 8, 42);
        assert_eq!(p.len(), m.layers.len());
        // ReLU on symmetric random data → ~40–65% zeros in mid layers
        let mid: Vec<f64> = p[5..p.len() - 5].iter().map(|l| l.act_sparsity).collect();
        let mean = mid.iter().sum::<f64>() / mid.len() as f64;
        assert!((0.3..0.75).contains(&mean), "mean act sparsity {mean}");
        // layer-to-layer variation exists (Fig 11's per-layer wiggle).
        // Synthetic random weights give less spread than real ImageNet
        // activations — assert the variation is non-degenerate.
        let min = mid.iter().cloned().fold(f64::MAX, f64::min);
        let max = mid.iter().cloned().fold(0.0f64, f64::max);
        assert!(max - min > 0.01, "no variation min={min} max={max}");
    }

    #[test]
    fn vdbb_network_faster_at_higher_sparsity() {
        let m = models::resnet50();
        let d = crate::arch::Design::paper_optimal();
        let p2 = profile_model_fixed_act(&m, 2, 8, 0.5);
        let p6 = profile_model_fixed_act(&m, 6, 8, 0.5);
        let t2 = network_timing(&d, &p2);
        let t6 = network_timing(&d, &p6);
        assert!(
            t2.total.cycles * 2 < t6.total.cycles,
            "2/8 {} vs 6/8 {}",
            t2.total.cycles,
            t6.total.cycles
        );
    }

    #[test]
    fn effective_tops_scales_like_paper_fig12() {
        // VDBB at 1/8 weight density ≈ 8× the 8/8 rate on a big model
        let m = models::vgg16();
        let d = crate::arch::Design::paper_optimal();
        let p1 = profile_model_fixed_act(&m, 1, 8, 0.5);
        let p8 = profile_model_fixed_act(&m, 8, 8, 0.5);
        let e1 = network_timing(&d, &p1).effective_tops(&d);
        let e8 = network_timing(&d, &p8).effective_tops(&d);
        let ratio = e1 / e8;
        assert!((6.0..=8.2).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn baseline_sa_flat_in_weight_sparsity() {
        let m = models::convnet5();
        let d = crate::arch::Design::baseline_sa();
        let p2 = profile_model_fixed_act(&m, 2, 8, 0.5);
        let p8 = profile_model_fixed_act(&m, 8, 8, 0.5);
        let c2 = network_timing(&d, &p2).total.cycles;
        let c8 = network_timing(&d, &p8).total.cycles;
        assert_eq!(c2, c8);
    }

    #[test]
    fn mcu_never_bottlenecks_vdbb_resnet() {
        // paper §IV-D: MCU provisioning keeps ancillary ops off the critical
        // path for typical layers (requant+relu vs GEMM)
        let m = models::resnet50();
        let d = crate::arch::Design::paper_optimal();
        let p = profile_model_fixed_act(&m, 3, 8, 0.5);
        let t = network_timing(&d, &p);
        let bottlenecked = t
            .layers
            .iter()
            .filter(|l| l.events.mcu_cycles > l.events.cycles)
            .count();
        // a few tiny 1×1 layers may be MCU-bound; the bulk must not be
        assert!(
            (bottlenecked as f64) < 0.35 * t.layers.len() as f64,
            "{bottlenecked}/{} layers MCU-bound",
            t.layers.len()
        );
    }

    #[test]
    fn depthwise_layers_fall_back_dense() {
        let m = models::mobilenet_v1();
        let p = profile_model_fixed_act(&m, 4, 8, 0.5);
        let dw = p.iter().find(|l| l.name.contains("/dw")).unwrap();
        assert_eq!(dw.weights.bound, 8); // dense
        let pw = p.iter().find(|l| l.name.contains("/pw")).unwrap();
        assert_eq!(pw.weights.bound, 4); // DBB 4/8
    }

    #[test]
    fn resnet_stripes_fit_paper_buffers() {
        // §IV-B: every layer's per-stripe weight working set and its raw
        // input activations fit the double-buffered WB/AB; the big late
        // layers just take multiple WB DMA phases
        let m = models::resnet50();
        let p = profile_model_fixed_act(&m, 3, 8, 0.5);
        let d = crate::arch::Design::paper_optimal();
        let feas = buffer_feasibility(&p, d.dims.c * d.dims.n);
        for f in &feas {
            assert!(f.stripe_fits, "{}: stripe {}B exceeds WB", f.name, f.stripe_bytes);
            assert!(f.acts_fit, "{}: acts {}B exceed AB", f.name, f.act_bytes);
            assert!(f.wb_phases >= 1);
        }
        // the late 3x3 layers genuinely need several phases
        let blk4 = feas.iter().find(|f| f.name == "blk4/unit2/conv2").unwrap();
        assert!(blk4.wb_phases > 1, "phases={}", blk4.wb_phases);
    }

    #[test]
    fn buffer_feasibility_dense_layer_excludes_index_bytes() {
        // regression for the ~12.5% WB overcount: a dense-fallback layer
        // (bound == bz) streams raw weights with no bitmask, so its bytes
        // are exactly kblocks·bz·n — pinned here
        let mk = |bound: usize| LayerProfile {
            name: format!("l_{bound}"),
            m: 64,
            weights: WeightStats::synthetic(64, 32, 8, bound),
            format: crate::gemm::WeightFormat::Dbb,
            act_sparsity: 0.5,
            act_encoded: false,
            im2col_magnification: 1.0,
            raw_act_bytes: 4096,
            out_elems: 64 * 32,
            relu: true,
            fused_epilogue: false,
        };
        let feas = buffer_feasibility(&[mk(8), mk(3)], 16);
        // dense: 8 kblocks × 8 B × 32 cols, no index overhead
        assert_eq!(feas[0].weight_bytes, 8 * 8 * 32);
        assert_eq!(feas[0].stripe_bytes, 8 * 8 * 16);
        // DBB 3/8 still pays 1 index byte per block: 8 × (3 + 1) × 32
        assert_eq!(feas[1].weight_bytes, 8 * (3 + 1) * 32);
        assert_eq!(feas[1].stripe_bytes, 8 * (3 + 1) * 16);
        for f in &feas {
            assert_eq!(
                f.wb_phases,
                f.weight_bytes.div_ceil(crate::sim::sram::Sram::weight_buffer().usable())
            );
        }
    }

    #[test]
    fn buffer_feasibility_bsr_layer_has_no_bitmask_byte() {
        // satellite regression: a BSR layer's WB working set is surviving
        // dense block values + row_ptr/col_idx — NOT the DBB per-block
        // bitmask byte. Exact bytes pinned.
        let mk = |format: crate::gemm::WeightFormat| LayerProfile {
            name: "l".into(),
            m: 64,
            weights: WeightStats::synthetic(64, 32, 8, 4),
            format,
            act_sparsity: 0.5,
            act_encoded: false,
            im2col_magnification: 1.0,
            raw_act_bytes: 4096,
            out_elems: 64 * 32,
            relu: true,
            fused_epilogue: false,
        };
        let feas = buffer_feasibility(
            &[mk(crate::gemm::WeightFormat::Bsr), mk(crate::gemm::WeightFormat::Dbb)],
            16,
        );
        // BSR at 50% block density: 4-of-8 kblocks survive per column.
        // values 4·8·32 + row_ptr 4·(8+1) + col_idx 2·(4 surviving × 4
        // block-cols) = 1024 + 36 + 32
        assert_eq!(feas[0].weight_bytes, 4 * 8 * 32 + 4 * 9 + 2 * 4 * 4);
        // 16-col stripe: values 4·8·16 + row_ptr + col_idx for 2 block-cols
        assert_eq!(feas[0].stripe_bytes, 4 * 8 * 16 + 4 * 9 + 2 * 4 * 2);
        // the DBB stream at the same density pays the bitmask byte instead
        assert_eq!(feas[1].weight_bytes, 8 * (4 + 1) * 32);
        assert!(feas[0].weight_bytes < feas[1].weight_bytes);
        // a dense-fallback BSR layer is the raw stream, same as dense DBB
        let mut dense = mk(crate::gemm::WeightFormat::Bsr);
        dense.weights = WeightStats::synthetic(64, 32, 8, 8);
        let df = buffer_feasibility(&[dense], 16);
        assert_eq!(df[0].weight_bytes, 8 * 8 * 32);
    }

    #[test]
    fn encoded_act_layer_prices_compressed_stream() {
        // the acceptance check: the twin's reported A-side operand bytes
        // drop when a layer's activations are encoded, with the index
        // metadata priced separately — and nothing else moves
        let mk = |enc: bool| LayerProfile {
            name: "l".into(),
            m: 256,
            weights: WeightStats::synthetic(512, 64, 8, 3),
            format: crate::gemm::WeightFormat::Dbb,
            act_sparsity: 0.6,
            act_encoded: enc,
            im2col_magnification: 1.0,
            raw_act_bytes: 256 * 512,
            out_elems: 256 * 64,
            relu: true,
            fused_epilogue: false,
        };
        let d = crate::arch::Design::paper_optimal();
        let mcu = McuComplex::for_tops(d.peak_effective_tops());
        let raw = layer_timing(&d, &mk(false), &mcu);
        let enc = layer_timing(&d, &mk(true), &mcu);
        assert_eq!(raw.events.act_index_bytes, 0);
        assert!(enc.events.act_index_bytes > 0);
        assert!(enc.events.act_sram_bytes < raw.events.act_sram_bytes);
        assert!(
            enc.events.act_sram_bytes + enc.events.act_index_bytes < raw.events.act_sram_bytes,
            "compressed stream must undercut the raw fetch at 60% zeros"
        );
        assert_eq!(enc.events.cycles, raw.events.cycles);
        assert_eq!(enc.events.macs_gated, raw.events.macs_gated);
        // and the AB working-set model shrinks the same way
        let feas = buffer_feasibility(&[mk(false), mk(true)], 16);
        assert!(feas[1].act_bytes < feas[0].act_bytes);
        assert_eq!(feas[0].act_bytes, 256 * 512);
    }

    #[test]
    fn parallel_profile_and_timing_match_serial() {
        // the worker-pool paths must be bit-identical to the serial ones
        let m = models::convnet5();
        let ps = profile_model_with(&m, 3, 8, 42, Parallelism::serial());
        let pp = profile_model_with(&m, 3, 8, 42, Parallelism::threads(4));
        assert_eq!(ps.len(), pp.len());
        for (a, b) in ps.iter().zip(&pp) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.act_sparsity.to_bits(), b.act_sparsity.to_bits(), "{}", a.name);
        }
        let d = crate::arch::Design::paper_optimal();
        let ts = network_timing(&d, &ps);
        let tp = network_timing_with(&d, &ps, Parallelism::threads(4));
        assert_eq!(ts.total, tp.total);
        assert_eq!(ts.dense_macs, tp.dense_macs);
    }

    #[test]
    fn recorded_act_sparsity_is_raw_input_zero_fraction() {
        // Pin the convention the LayerProfile docs promise: act_sparsity is
        // the zero fraction of the layer's raw fitted *input* operand,
        // before IM2COL expansion. For layer 0 the fitted input IS the
        // stored seed input (identity fit), so the recorded value must
        // equal its zero fraction to the bit.
        let m = models::convnet5();
        let mut pm = crate::engine::PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
        let profiles = pm.profile(Parallelism::serial());
        let seed_s = pm.seed_input().sparsity();
        assert_eq!(
            profiles[0].act_sparsity.to_bits(),
            seed_s.to_bits(),
            "layer 0 act_sparsity {} != seed input zero fraction {}",
            profiles[0].act_sparsity,
            seed_s
        );
        // and it is an *input*-side quantity: the near-dense seed input
        // (2% zeros) must not be confused with layer 0's post-ReLU output
        assert!(profiles[0].act_sparsity < 0.1);
    }

    #[test]
    fn fused_epilogue_moves_post_processing_off_the_mcu() {
        // same layer, staged vs fused: the post-processing cycles move from
        // the MCU column to the epilogue counter — nothing else changes,
        // and a layer whose MCU column used to gate it stops being gated
        // by it only if the epilogue is also faster than the array (here
        // the counters are equal, so seconds() is unchanged too)
        let mk = |fused: bool| LayerProfile {
            name: "l".into(),
            m: 256,
            weights: WeightStats::synthetic(512, 64, 8, 3),
            format: crate::gemm::WeightFormat::Dbb,
            act_sparsity: 0.5,
            act_encoded: false,
            im2col_magnification: 1.0,
            raw_act_bytes: 256 * 512,
            out_elems: 256 * 64,
            relu: true,
            fused_epilogue: fused,
        };
        let d = crate::arch::Design::paper_optimal();
        let mcu = McuComplex::for_tops(d.peak_effective_tops());
        let staged = layer_timing(&d, &mk(false), &mcu);
        let fused = layer_timing(&d, &mk(true), &mcu);
        assert!(staged.events.mcu_cycles > 0);
        assert_eq!(staged.events.epilogue_cycles, 0);
        assert_eq!(fused.events.mcu_cycles, 0);
        assert_eq!(fused.events.epilogue_cycles, staged.events.mcu_cycles);
        assert_eq!(fused.events.cycles, staged.events.cycles);
        assert_eq!(fused.events.act_sram_bytes, staged.events.act_sram_bytes);
        // totals aggregate the new counter
        let ts = network_timing(&d, &[mk(false)]);
        let tf = network_timing(&d, &[mk(true)]);
        assert_eq!(tf.total.epilogue_cycles, ts.total.mcu_cycles);
        assert_eq!(tf.total.mcu_cycles, 0);
        assert_eq!(ts.seconds(&d).to_bits(), tf.seconds(&d).to_bits());
    }

    #[test]
    fn requant_preserves_zero_and_saturates() {
        let acc = crate::tensor::TensorI32::from_vec(&[4], vec![0, 100_000, -100_000, 127]);
        let out = requant_relu(&acc, false);
        assert_eq!(out.data()[0], 0);
        assert!(out.data()[1] > 0);
        assert!(out.data()[2] < 0);
    }
}
