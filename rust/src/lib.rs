//! # ssta — Sparse Systolic Tensor Array
//!
//! A full-system reproduction of *"Sparse Systolic Tensor Array for Efficient
//! CNN Hardware Acceleration"* (Liu, Whatmough, Mattina — Arm ML Research,
//! 2020), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the architecture simulator and coordinator:
//!   cycle-accurate models of the SA / STA / STA-DBB / STA-VDBB datapath
//!   arrays, the hardware IM2COL unit, local SRAMs and the M33 MCUs; a
//!   calibrated 16 nm / 65 nm power + area model; the design-space explorer;
//!   a pure-Rust CNN training substrate for the DBB-pruning experiments; and
//!   an inference coordinator that serves batched requests through the
//!   [`engine`]'s prepared models (registry-cached, persisted as flat
//!   binaries) while the timing path runs on the simulator twin — the
//!   legacy AOT-compiled XLA functional path is preserved behind
//!   `Config::use_xla`.
//! * **Layer 2 (python/compile/model.py)** — the CNN forward pass in JAX,
//!   lowered once to HLO text artifacts consumed by [`runtime`].
//! * **Layer 1 (python/compile/kernels/)** — the DBB-sparse GEMM hot-spot as
//!   a Pallas kernel (interpret mode), checked against a pure-jnp oracle.
//!
//! See `ARCHITECTURE.md` for the paper-section → module map (one paragraph
//! per subsystem, with entry points), and `README.md` for the workload zoo,
//! build/CI gates and environment knobs.

// a dangling intra-doc link is a broken promise to the reader: deny it
// outright so `cargo doc` / `cargo test --doc` fail on rename drift
#![deny(rustdoc::broken_intra_doc_links)]

pub mod arch;
pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod dbb;
pub mod engine;
pub mod gemm;
pub mod harness;
pub mod models;
pub mod power;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod train;
pub mod util;
