//! # ssta — Sparse Systolic Tensor Array
//!
//! A full-system reproduction of *"Sparse Systolic Tensor Array for Efficient
//! CNN Hardware Acceleration"* (Liu, Whatmough, Mattina — Arm ML Research,
//! 2020), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the architecture simulator and coordinator:
//!   cycle-accurate models of the SA / STA / STA-DBB / STA-VDBB datapath
//!   arrays, the hardware IM2COL unit, local SRAMs and the M33 MCUs; a
//!   calibrated 16 nm / 65 nm power + area model; the design-space explorer;
//!   a pure-Rust CNN training substrate for the DBB-pruning experiments; and
//!   an inference coordinator that serves batched requests, running the
//!   functional path on AOT-compiled XLA executables while the timing path
//!   runs on the simulator.
//! * **Layer 2 (python/compile/model.py)** — the CNN forward pass in JAX,
//!   lowered once to HLO text artifacts consumed by [`runtime`].
//! * **Layer 1 (python/compile/kernels/)** — the DBB-sparse GEMM hot-spot as
//!   a Pallas kernel (interpret mode), checked against a pure-jnp oracle.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index
//! mapping every table and figure of the paper to a module and bench target.

pub mod arch;
pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod dbb;
pub mod engine;
pub mod gemm;
pub mod harness;
pub mod models;
pub mod power;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod train;
pub mod util;
