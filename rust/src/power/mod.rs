//! Power + area model (paper §V-C / Table IV).
//!
//! The paper evaluates synthesized RTL with PrimeTimePX on VCD switching
//! activity. We reproduce the same *derivation structure* with an
//! analytical component model: the simulator counts every switching event
//! (active/gated/idle MAC slots, mux selects, SRAM bytes, register clocks),
//! and this module multiplies them by per-event energies and per-component
//! areas from a technology library.
//!
//! The 16 nm library is **calibrated once** against the paper's own Table IV
//! breakdown (318 mW STA / 78.5 mW WSRAM / 31 mW ASRAM / 50.5 mW MCU /
//! 10 mW IM2COL at the 3/8-DBB + 50%-activation operating point of the
//! optimal design); every *other* design point, sparsity level and layer mix
//! is then a genuine model prediction. See [`calib`] for the anchor
//! constants and `EXPERIMENTS.md` for the residuals.

pub mod calib;

use crate::arch::{reuse, Design, Tech};
use crate::sim::mcu::McuComplex;
use crate::sim::EventCounts;

/// Per-event energy library (picojoules) + per-component area library
/// (µm² / mm²) for one technology node.
#[derive(Debug, Clone, Copy)]
pub struct TechLib {
    /// Active INT8 MAC (full operand switching), incl. local wiring.
    pub e_mac_active_pj: f64,
    /// Zero-operand MAC slot on a data-gated (non-CG) datapath: operands
    /// still clock through registers, multiplier doesn't toggle.
    pub e_mac_data_gated_pj: f64,
    /// Clock-gated MAC slot (CG-capable datapath): gater + residual clock.
    pub e_mac_clock_gated_pj: f64,
    /// Idle-but-clocked MAC slot (utilization loss).
    pub e_mac_idle_pj: f64,
    /// 8:1 INT8 mux select.
    pub e_mux_pj: f64,
    /// One operand-register byte clocked for one cycle.
    pub e_opr_reg_byte_pj: f64,
    /// One INT32 accumulator update.
    pub e_acc_update_pj: f64,
    /// Weight-buffer SRAM access per byte (512 KB instance).
    pub e_wsram_byte_pj: f64,
    /// Activation-buffer SRAM access per byte (2 MB instance — the larger
    /// macro's longer bitlines/wordlines cost more per access; the
    /// bank-muxing parameter of §IV-B trades this against area).
    pub e_asram_byte_pj: f64,
    /// IM2COL unit energy per edge byte produced.
    pub e_im2col_byte_pj: f64,
    /// MCU complex power per core (mW) while the accelerator runs.
    pub mcu_mw_per_core: f64,
    /// Clock-tree + misc overhead as a fraction of datapath dynamic power.
    pub clock_overhead: f64,

    /// INT8 MAC area (µm²).
    pub a_mac_um2: f64,
    /// 8:1 mux area (µm²).
    pub a_mux_um2: f64,
    /// Register area per bit (µm²).
    pub a_reg_bit_um2: f64,
    /// SRAM macro area per MB (mm²).
    pub a_sram_mm2_per_mb: f64,
    /// MCU area per core incl. 64 KB program SRAM (mm²).
    pub a_mcu_mm2_per_core: f64,
    /// IM2COL unit area (mm²).
    pub a_im2col_mm2: f64,
}

impl TechLib {
    /// Library for a node.
    pub fn for_tech(t: Tech) -> TechLib {
        match t {
            Tech::N16 => calib::LIB_16NM,
            Tech::N65 => calib::LIB_65NM,
        }
    }
}

/// Power breakdown in mW (Table IV rows).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PowerBreakdown {
    /// Systolic tensor array (MACs, muxes, registers, clock).
    pub sta_mw: f64,
    /// Weight SRAM.
    pub wsram_mw: f64,
    /// Activation SRAM.
    pub asram_mw: f64,
    /// MCU complex.
    pub mcu_mw: f64,
    /// IM2COL unit.
    pub im2col_mw: f64,
}

impl PowerBreakdown {
    /// Total power.
    pub fn total_mw(&self) -> f64 {
        self.sta_mw + self.wsram_mw + self.asram_mw + self.mcu_mw + self.im2col_mw
    }
}

/// Area breakdown in mm² (Table IV rows).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AreaBreakdown {
    /// Systolic tensor array.
    pub sta_mm2: f64,
    /// Weight SRAM (512 KB).
    pub wsram_mm2: f64,
    /// Activation SRAM (2 MB).
    pub asram_mm2: f64,
    /// MCU complex.
    pub mcu_mm2: f64,
    /// IM2COL unit.
    pub im2col_mm2: f64,
}

impl AreaBreakdown {
    /// Total area.
    pub fn total_mm2(&self) -> f64 {
        self.sta_mm2 + self.wsram_mm2 + self.asram_mm2 + self.mcu_mm2 + self.im2col_mm2
    }
}

/// Area of a design (workload independent).
pub fn area(design: &Design) -> AreaBreakdown {
    let lib = TechLib::for_tech(design.tech);
    let macs = design.physical_macs() as f64;
    let muxes = design.muxes() as f64;
    let opr_bits = design.opr_regs() as f64 * 8.0;
    let acc_bits = design.acc_regs() as f64 * 32.0;
    let sta_um2 =
        macs * lib.a_mac_um2 + muxes * lib.a_mux_um2 + (opr_bits + acc_bits) * lib.a_reg_bit_um2;
    let mcu = McuComplex::for_tops(design.peak_effective_tops());
    AreaBreakdown {
        sta_mm2: sta_um2 / 1e6,
        wsram_mm2: 0.5 * lib.a_sram_mm2_per_mb,
        asram_mm2: 2.0 * lib.a_sram_mm2_per_mb,
        mcu_mm2: mcu.cores as f64 * lib.a_mcu_mm2_per_core,
        im2col_mm2: if design.im2col { lib.a_im2col_mm2 } else { 0.0 },
    }
}

/// Average power while executing a workload described by `events`
/// (the counters already aggregate the whole run; power = energy / time).
///
/// Neither `mcu_cycles` nor `epilogue_cycles` enters the formula: the MCU
/// complex is priced constant-while-running, and the fused-epilogue output
/// walk reuses datapath cycles that are already charged. Relocating a
/// layer's post-processing between the two counters (staged MCU chain vs
/// `execute_fused`) is therefore power-neutral by construction — the
/// output writeback was already priced as requantized INT8 in the
/// analytic event model, so fusion changes *where* the cycles are
/// accounted (Fig-11 normalization), not the energy.
pub fn power(design: &Design, events: &EventCounts) -> PowerBreakdown {
    let lib = TechLib::for_tech(design.tech);
    if events.cycles == 0 {
        return PowerBreakdown::default();
    }
    let seconds = events.cycles as f64 / design.tech.freq_hz();

    // ---- datapath energy ----
    let cg = reuse::act_cg_effective(design) && design.act_cg;
    let e_gated = if cg {
        lib.e_mac_clock_gated_pj
    } else {
        lib.e_mac_data_gated_pj
    };
    let acc_updates =
        (events.macs_active + events.macs_gated) as f64 / reuse::acc_reuse(design) as f64;
    let opr_reg_bytes = design.opr_regs() as f64; // clocked every cycle
    let mut sta_pj = events.macs_active as f64 * lib.e_mac_active_pj
        + events.macs_gated as f64 * e_gated
        + events.macs_idle as f64 * lib.e_mac_idle_pj
        + events.mux_selects as f64 * lib.e_mux_pj
        + opr_reg_bytes * events.cycles as f64 * lib.e_opr_reg_byte_pj
        + acc_updates * lib.e_acc_update_pj;
    sta_pj *= 1.0 + lib.clock_overhead;

    // ---- SRAM energy ----
    let wsram_pj = events.weight_sram_bytes as f64 * lib.e_wsram_byte_pj;
    // act_index_bytes is the A-side DBB bitmask metadata of encoded layers:
    // it streams from the same activation SRAM as the values it selects
    let asram_pj = (events.act_sram_bytes + events.act_index_bytes + events.out_sram_bytes)
        as f64
        * lib.e_asram_byte_pj;

    // ---- IM2COL unit ----
    let im2col_pj = if design.im2col {
        events.act_edge_bytes as f64 * lib.e_im2col_byte_pj
    } else {
        0.0
    };

    // ---- MCU: constant while running ----
    let mcu = McuComplex::for_tops(design.peak_effective_tops());
    let mcu_mw = mcu.cores as f64 * lib.mcu_mw_per_core;

    let to_mw = |pj: f64| pj * 1e-12 / seconds * 1e3;
    PowerBreakdown {
        sta_mw: to_mw(sta_pj),
        wsram_mw: to_mw(wsram_pj),
        asram_mw: to_mw(asram_pj),
        mcu_mw,
        im2col_mw: to_mw(im2col_pj),
    }
}

/// Energy efficiency in effective TOPS/W for a workload run.
pub fn effective_tops_per_w(design: &Design, events: &EventCounts, dense_macs: u64) -> f64 {
    let p = power(design, events).total_mw() / 1e3; // W
    let seconds = events.cycles as f64 / design.tech.freq_hz();
    let eff_tops = 2.0 * dense_macs as f64 / seconds / 1e12;
    eff_tops / p
}

/// Area efficiency in effective TOPS/mm² for a workload run.
pub fn effective_tops_per_mm2(design: &Design, events: &EventCounts, dense_macs: u64) -> f64 {
    let a = area(design).total_mm2();
    let seconds = events.cycles as f64 / design.tech.freq_hz();
    let eff_tops = 2.0 * dense_macs as f64 / seconds / 1e12;
    eff_tops / a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Design;
    use crate::sim::accel::{network_timing, profile_model_fixed_act, profile_model_repr};

    /// The Table IV operating point: optimal design, 3/8 DBB, 50% act,
    /// representative (3×3) ResNet-50 layers — the paper's §V-C power
    /// workload.
    fn table4_run() -> (Design, crate::sim::accel::NetworkTiming) {
        let d = Design::paper_optimal();
        let m = crate::models::resnet50();
        let p = profile_model_repr(&m, 3, 8, 0.5);
        let t = network_timing(&d, &p);
        (d, t)
    }

    #[test]
    fn table4_power_within_calibration_tolerance() {
        let (d, t) = table4_run();
        let p = power(&d, &t.total);
        // paper: STA 318, WSRAM 78.5, ASRAM 31, MCU 50.5, IM2COL 10, total 487.5
        assert!((p.sta_mw - 318.0).abs() / 318.0 < 0.20, "sta={}", p.sta_mw);
        assert!((p.wsram_mw - 78.5).abs() / 78.5 < 0.35, "wsram={}", p.wsram_mw);
        assert!((p.mcu_mw - 50.5).abs() / 50.5 < 0.20, "mcu={}", p.mcu_mw);
        assert!(
            (p.total_mw() - 487.5).abs() / 487.5 < 0.20,
            "total={}",
            p.total_mw()
        );
    }

    #[test]
    fn table4_area_within_tolerance() {
        let (d, _) = table4_run();
        let a = area(&d);
        // paper: STA 0.732, WSRAM 0.54, ASRAM 2.16, MCU 0.30, total 3.74
        assert!((a.sta_mm2 - 0.732).abs() / 0.732 < 0.20, "sta={}", a.sta_mm2);
        assert!((a.wsram_mm2 - 0.54).abs() / 0.54 < 0.10, "w={}", a.wsram_mm2);
        assert!((a.asram_mm2 - 2.16).abs() / 2.16 < 0.10, "a={}", a.asram_mm2);
        assert!(
            (a.total_mm2() - 3.74).abs() / 3.74 < 0.15,
            "total={}",
            a.total_mm2()
        );
    }

    #[test]
    fn table4_efficiency_headline() {
        // paper: 21.9 TOPS/W, 2.85 TOPS/mm² at 62.5% sparsity
        let (d, t) = table4_run();
        let tw = effective_tops_per_w(&d, &t.total, t.dense_macs);
        assert!((15.0..30.0).contains(&tw), "TOPS/W={tw}");
        let tm = effective_tops_per_mm2(&d, &t.total, t.dense_macs);
        assert!((2.0..4.0).contains(&tm), "TOPS/mm2={tm}");
    }

    #[test]
    fn vdbb_power_relatively_flat_in_weight_sparsity() {
        // paper §VI-A: "power consumption of proposed microarch. with DBB
        // weights is fairly constant"
        let d = Design::paper_optimal();
        let m = crate::models::resnet50();
        let p2 = network_timing(&d, &profile_model_fixed_act(&m, 2, 8, 0.5));
        let p6 = network_timing(&d, &profile_model_fixed_act(&m, 6, 8, 0.5));
        let w2 = power(&d, &p2.total).total_mw();
        let w6 = power(&d, &p6.total).total_mw();
        assert!(
            (w2 / w6 - 1.0).abs() < 0.35,
            "2/8 {w2} mW vs 6/8 {w6} mW"
        );
    }

    #[test]
    fn act_sparsity_lowers_power() {
        let d = Design::paper_optimal();
        let m = crate::models::resnet50();
        let p50 = network_timing(&d, &profile_model_fixed_act(&m, 3, 8, 0.5));
        let p80 = network_timing(&d, &profile_model_fixed_act(&m, 3, 8, 0.8));
        assert!(
            power(&d, &p80.total).total_mw() < power(&d, &p50.total).total_mw()
        );
    }

    #[test]
    fn im2col_cuts_asram_power_about_3x_on_3x3_nets() {
        // VGG-16 is all 3×3 convs → full 3× magnification benefit
        let m = crate::models::vgg16();
        let mut with = Design::paper_optimal();
        with.im2col = true;
        let mut without = with;
        without.im2col = false;
        let pw = profile_model_fixed_act(&m, 3, 8, 0.5);
        let tw = network_timing(&with, &pw);
        let to = network_timing(&without, &pw);
        let a_with = power(&with, &tw.total).asram_mw;
        let a_without = power(&without, &to.total).asram_mw;
        let ratio = a_without / a_with;
        assert!((2.0..3.3).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn tops_per_w_scales_with_sparsity_like_fig12() {
        let d = Design::paper_optimal();
        let m = crate::models::resnet50();
        let mut prev = 0.0;
        for nnz in (1..=8).rev() {
            let t = network_timing(&d, &profile_model_fixed_act(&m, nnz, 8, 0.5));
            let tw = effective_tops_per_w(&d, &t.total, t.dense_macs);
            assert!(tw > prev, "nnz={nnz} tw={tw} prev={prev}");
            prev = tw;
        }
    }

    #[test]
    fn power_invariant_under_epilogue_relocation() {
        // Moving a layer's post-processing cycles from the MCU column to
        // the fused-epilogue column must not change any power row: the MCU
        // is constant-while-running and the fused walk reuses already-priced
        // datapath cycles. Guards against double-charging (or phantom
        // savings) when the engine declares `fused_epilogue`.
        let (d, t) = table4_run();
        let mut staged = t.total;
        staged.mcu_cycles += staged.epilogue_cycles;
        staged.epilogue_cycles = 0;
        let mut fused = staged;
        fused.epilogue_cycles = staged.mcu_cycles;
        fused.mcu_cycles = 0;
        assert_eq!(power(&d, &staged), power(&d, &fused));
    }

    #[test]
    fn zero_cycles_zero_power() {
        let d = Design::paper_optimal();
        let e = EventCounts::default();
        assert_eq!(power(&d, &e).total_mw(), 0.0);
    }
}
