//! Calibration anchors for the technology libraries.
//!
//! The paper's power/area numbers come from PrimeTimePX on parasitic
//! annotated 16 nm netlists — unavailable here, so we substitute an
//! analytical component model (DESIGN.md §Paper-resources substitutions)
//! whose per-event energies are **calibrated once** against the paper's own
//! Table IV breakdown at its quoted operating point (optimal VDBB design,
//! ResNet-50 with 3/8 DBB weights and 50% random-sparse activations):
//!
//! | component | paper Table IV | model target |
//! |---|---|---|
//! | Systolic tensor array | 318 mW / 0.732 mm² | anchor |
//! | Weight SRAM (512 KB)  | 78.5 mW / 0.54 mm² | anchor |
//! | Activation SRAM (2 MB)| 31.0 mW (93.0 no-IM2C) / 2.16 mm² | anchor |
//! | Cortex-M33 MCUs       | 50.5 mW / 0.30 mm² | anchor |
//! | IM2COL unit           | 10.0 mW / 0.01 mm² | anchor |
//!
//! Every constant below is a physically plausible 16 nm per-event cost
//! (cross-checked against the usual pJ/op literature values: INT8 MAC
//! ≈0.05–0.3 pJ, large SRAM read ≈5–20 pJ/word, register ≈1–10 fJ/bit) and
//! scaled so the anchor design lands on Table IV; the residuals we accept
//! are recorded in `EXPERIMENTS.md`. Every *other* design point — different
//! array shapes, datapaths, sparsity levels, layers — is then a genuine
//! model prediction, which is what reproduces the *shapes* of Figs 9–12.
//!
//! The 65 nm LP library is derived from the 16 nm one with conventional
//! node-scaling factors (dynamic energy ×~6 at the higher VDD and larger
//! caps, area ×~9 for logic, ×~8 for SRAM macros), sanity-checked against
//! the paper's 65 nm rows of Table V (2.80 TOPS/W at 75% VDBB).

use super::TechLib;

/// TSMC 16 nm FinFET @ 1 GHz (paper's primary node).
pub const LIB_16NM: TechLib = TechLib {
    // --- datapath per-event energies (pJ) ---
    e_mac_active_pj: 0.143,
    e_mac_data_gated_pj: 0.055,
    e_mac_clock_gated_pj: 0.018,
    e_mac_idle_pj: 0.030,
    e_mux_pj: 0.008,
    e_opr_reg_byte_pj: 0.018,
    e_acc_update_pj: 0.030,
    // --- memory ---
    e_wsram_byte_pj: 0.92,
    e_asram_byte_pj: 1.07,
    e_im2col_byte_pj: 0.131,
    // --- MCU (paper Table IV: 50.5 mW for the complex; the optimal VDBB
    // design provisions the maximum 8 cores → 6.3 mW/core, consistent with
    // an M33-class core + 64 KB program SRAM + DMA running flat out) ---
    mcu_mw_per_core: 6.31,
    // clock tree + global distribution on top of datapath dynamic power
    clock_overhead: 0.18,

    // --- areas ---
    a_mac_um2: 245.0,
    a_mux_um2: 30.0,
    a_reg_bit_um2: 2.0,
    a_sram_mm2_per_mb: 1.08,
    a_mcu_mm2_per_core: 0.0375,
    a_im2col_mm2: 0.01,
};

/// TSMC 65 nm LP bulk @ 500 MHz (paper's comparison node).
///
/// Scaling from 16 nm: dynamic energy ×10.7 — calibrated to the paper's
/// own 65 nm rows of Table V (2.80 TOPS/W at 75% VDBB; a plain capacitance
/// argument gives ×6, but the 65 nm LP library also runs at higher VDD and
/// the paper's 65 nm numbers imply the larger factor). Logic area ×9, SRAM
/// macro area ×8 (bitcell 0.5 µm² class vs 0.074 µm² class plus
/// periphery).
pub const LIB_65NM: TechLib = TechLib {
    e_mac_active_pj: 0.143 * 10.7,
    e_mac_data_gated_pj: 0.055 * 10.7,
    e_mac_clock_gated_pj: 0.018 * 10.7,
    e_mac_idle_pj: 0.030 * 10.7,
    e_mux_pj: 0.008 * 10.7,
    e_opr_reg_byte_pj: 0.018 * 10.7,
    e_acc_update_pj: 0.030 * 10.7,
    e_wsram_byte_pj: 0.92 * 10.7,
    e_asram_byte_pj: 1.07 * 10.7,
    e_im2col_byte_pj: 0.131 * 10.7,
    mcu_mw_per_core: 11.3, // scaled with the node energy factor
    clock_overhead: 0.18,

    a_mac_um2: 245.0 * 9.0,
    a_mux_um2: 30.0 * 9.0,
    a_reg_bit_um2: 2.0 * 9.0,
    a_sram_mm2_per_mb: 1.08 * 8.0,
    a_mcu_mm2_per_core: 0.0375 * 9.0,
    a_im2col_mm2: 0.01 * 9.0,
};

#[cfg(test)]
mod tests {
    use crate::arch::Design;
    use crate::power;
    use crate::sim::accel::{network_timing, profile_model_repr};

    /// Dump the anchor-run component powers next to the Table IV targets
    /// (`cargo test calib_dump -- --nocapture --ignored` while re-tuning).
    #[test]
    #[ignore = "diagnostic dump for re-calibration"]
    fn calib_dump() {
        let d = Design::paper_optimal();
        let m = crate::models::resnet50();
        let p = profile_model_repr(&m, 3, 8, 0.5);
        let t = network_timing(&d, &p);
        let e = &t.total;
        let secs = e.cycles as f64 / d.tech.freq_hz();
        println!("anchor events over {secs:.6} s:");
        println!("  cycles          {}", e.cycles);
        println!("  macs_active     {}", e.macs_active);
        println!("  macs_gated      {}", e.macs_gated);
        println!("  macs_idle       {}", e.macs_idle);
        println!("  mux_selects     {}", e.mux_selects);
        println!("  weight_bytes    {}", e.weight_sram_bytes);
        println!("  act_bytes       {}", e.act_sram_bytes);
        println!("  act_edge_bytes  {}", e.act_edge_bytes);
        println!("  out_bytes       {}", e.out_sram_bytes);
        let pw = power::power(&d, e);
        println!(
            "power  (paper):   sta 318  wsram 78.5  asram 31.0  mcu 50.5  im2c 10.0  total 487.5"
        );
        println!(
            "power  (model):   sta {:.1}  wsram {:.1}  asram {:.1}  mcu {:.1}  im2c {:.1}  \
             total {:.1}",
            pw.sta_mw, pw.wsram_mw, pw.asram_mw, pw.mcu_mw, pw.im2col_mw, pw.total_mw()
        );
        let a = power::area(&d);
        println!(
            "area   (paper):   sta 0.732  wsram 0.54  asram 2.16  mcu 0.30  im2c 0.01  total 3.74"
        );
        println!(
            "area   (model):   sta {:.3}  wsram {:.3}  asram {:.3}  mcu {:.3}  im2c {:.3}  \
             total {:.3}",
            a.sta_mm2, a.wsram_mm2, a.asram_mm2, a.mcu_mm2, a.im2col_mm2, a.total_mm2()
        );
        println!(
            "efficiency: {:.1} TOPS/W (paper 21.9), {:.2} TOPS/mm2 (paper 2.85)",
            power::effective_tops_per_w(&d, e, t.dense_macs),
            power::effective_tops_per_mm2(&d, e, t.dense_macs),
        );
    }
}
