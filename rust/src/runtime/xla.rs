//! Offline stub for the `xla` PJRT bindings (`xla_extension`).
//!
//! The build environment has no network and no XLA shared library, so the
//! PJRT surface [`super`] compiles against is stubbed here with the same
//! type/method signatures. Every entry point that would touch PJRT returns
//! a descriptive error from [`PjRtClient::cpu`] onward — because the client
//! is the root handle, nothing downstream is reachable at runtime.
//!
//! Every runtime/coordinator test and the XLA bench path already gate on
//! `artifacts/manifest.json` existing (a clean checkout has no artifacts),
//! so the stub only ever surfaces as a clear "runtime unavailable" error
//! when someone points `ssta serve` at a real artifact directory.

// A stub by construction: several handle types can never be constructed
// (everything fails at `PjRtClient::cpu`), which is exactly what the
// never-constructed lint would flag.
#![allow(dead_code)]

use crate::util::error::{Error, Result};

const UNAVAILABLE: &str =
    "XLA/PJRT runtime unavailable in this offline build (xla_extension is not linked); \
     the functional serving path needs the artifact toolchain";

fn unavailable() -> Error {
    Error::msg(UNAVAILABLE)
}

/// Element types the artifacts use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit float.
    F32,
    /// 8-bit signed int.
    S8,
    /// 32-bit signed int.
    S32,
}

/// Host-side literal (stub).
#[derive(Debug)]
pub struct Literal;

impl Literal {
    /// Would build a literal over raw bytes.
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable())
    }

    /// Would return the literal's byte size.
    pub fn size_bytes(&self) -> usize {
        0
    }

    /// Would copy the literal out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }

    /// Would destructure a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Would copy the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Would execute with the given operands.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client handle (stub). [`PjRtClient::cpu`] always errors, which makes
/// every other stub method unreachable in practice.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Would create the CPU PJRT client.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    /// Would compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    /// Platform string for diagnostics.
    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Would parse HLO text from a file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// Computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Would wrap a proto as a computation.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }
}
