//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them from the Layer-3 hot path. Python never runs here — `make artifacts`
//! lowered the Layer-2/Layer-1 computations to HLO **text** once, and this
//! module parses, compiles and caches them on the CPU PJRT client.
//!
//! Text (not serialized `HloModuleProto`) is the interchange format: jax
//! ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see `/opt/xla-example/README.md`
//! and `python/compile/aot.py`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::error::{anyhow, bail, Context, Result};
use crate::util::json::Json;

mod xla;

/// Element dtype of an artifact operand (the manifest's `"dtype"` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 8-bit signed int (the INT8 datapath type).
    S8,
    /// 32-bit signed int (accumulators, index metadata).
    S32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "s8" => Dtype::S8,
            "s32" => Dtype::S32,
            other => bail!("unsupported dtype in manifest: {other}"),
        })
    }

    fn element_type(self) -> xla::ElementType {
        match self {
            Dtype::F32 => xla::ElementType::F32,
            Dtype::S8 => xla::ElementType::S8,
            Dtype::S32 => xla::ElementType::S32,
        }
    }

    fn size(self) -> usize {
        match self {
            Dtype::S8 => 1,
            _ => 4,
        }
    }
}

/// Shape + dtype of one artifact operand.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dimensions.
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: Dtype,
}

impl TensorSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = Dtype::parse(
            j.get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("missing dtype"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// Manifest entry for one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Artifact name (manifest key).
    pub name: String,
    /// HLO text file (relative to the artifact dir).
    pub file: String,
    /// Entry kind (`convnet5`, `dbb_gemm`, ...).
    pub entry: String,
    /// Input operand specs, in execute order.
    pub inputs: Vec<TensorSpec>,
    /// Output specs (artifacts are lowered with `return_tuple=True`).
    pub outputs: Vec<TensorSpec>,
    /// The raw manifest object (for entry-specific fields: batch, nnz,
    /// per-layer weight stats...).
    pub raw: Json,
}

/// A host-side tensor matching a [`TensorSpec`] — what the coordinator's
/// request path moves in and out of PJRT.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    /// f32 data.
    F32(Vec<f32>),
    /// i8 data.
    I8(Vec<i8>),
    /// i32 data.
    I32(Vec<i32>),
}

impl HostTensor {
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I8(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dtype of this tensor.
    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32(_) => Dtype::F32,
            HostTensor::I8(_) => Dtype::S8,
            HostTensor::I32(_) => Dtype::S32,
        }
    }

    /// View as f32 slice (panics on dtype mismatch).
    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32(v) => v,
            _ => panic!("dtype mismatch: wanted f32, got {:?}", self.dtype()),
        }
    }

    /// View as i32 slice.
    pub fn as_i32(&self) -> &[i32] {
        match self {
            HostTensor::I32(v) => v,
            _ => panic!("dtype mismatch: wanted i32, got {:?}", self.dtype()),
        }
    }

    /// View as i8 slice.
    pub fn as_i8(&self) -> &[i8] {
        match self {
            HostTensor::I8(v) => v,
            _ => panic!("dtype mismatch: wanted i8, got {:?}", self.dtype()),
        }
    }

    fn bytes(&self) -> &[u8] {
        match self {
            HostTensor::F32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
            HostTensor::I8(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len())
            },
            HostTensor::I32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
        }
    }

    fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        if self.dtype() != spec.dtype {
            bail!("operand dtype {:?} != spec {:?}", self.dtype(), spec.dtype);
        }
        if self.len() != spec.elems() {
            bail!(
                "operand has {} elems, spec {:?} wants {}",
                self.len(),
                spec.shape,
                spec.elems()
            );
        }
        xla::Literal::create_from_shape_and_untyped_data(
            spec.dtype.element_type(),
            &spec.shape,
            self.bytes(),
        )
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        debug_assert_eq!(lit.size_bytes(), spec.elems() * spec.dtype.size());
        Ok(match spec.dtype {
            Dtype::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
            Dtype::S8 => HostTensor::I8(lit.to_vec::<i8>()?),
            Dtype::S32 => HostTensor::I32(lit.to_vec::<i32>()?),
        })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Artifact metadata.
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Execute with host tensors; returns the tuple outputs as host tensors.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "artifact {} wants {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        let literals = inputs
            .iter()
            .zip(&self.meta.inputs)
            .map(|(t, s)| t.to_literal(s))
            .collect::<Result<Vec<_>>>()?;
        let bufs = self.exe.execute::<xla::Literal>(&literals)?;
        let result = bufs[0][0].to_literal_sync()?;
        // artifacts are lowered with return_tuple=True
        let outs = result.to_tuple()?;
        if outs.len() != self.meta.outputs.len() {
            bail!(
                "artifact {} returned {} outputs, manifest says {}",
                self.meta.name,
                outs.len(),
                self.meta.outputs.len()
            );
        }
        outs.iter()
            .zip(&self.meta.outputs)
            .map(|(l, s)| HostTensor::from_literal(l, s))
            .collect()
    }
}

/// The artifact runtime: PJRT CPU client + manifest + executable cache.
///
/// Not `Sync` (PJRT handles are thread-affine in the 0.1.6 crate); the
/// coordinator owns one `Runtime` on its executor thread and feeds it
/// through channels.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<String, ArtifactMeta>,
    cache: HashMap<String, std::rc::Rc<Executable>>,
}

impl Runtime {
    /// Open an artifact directory (reads `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} — run `make artifacts` first", mpath.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let obj = json.as_obj().ok_or_else(|| anyhow!("manifest is not an object"))?;
        let mut manifest = HashMap::new();
        for (name, meta) in obj {
            let get_str = |k: &str| -> Result<String> {
                Ok(meta
                    .get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact {name}: missing {k}"))?
                    .to_string())
            };
            let specs = |k: &str| -> Result<Vec<TensorSpec>> {
                meta.get(k)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("artifact {name}: missing {k}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            manifest.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: get_str("file")?,
                    entry: get_str("entry")?,
                    inputs: specs("inputs")?,
                    outputs: specs("outputs")?,
                    raw: meta.clone(),
                },
            );
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: HashMap::new(),
        })
    }

    /// Artifact names available.
    pub fn artifact_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.manifest.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// Manifest metadata for an artifact.
    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.get(name)
    }

    /// Load (compile) an artifact; compiled executables are cached.
    pub fn load(&mut self, name: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let e = std::rc::Rc::new(Executable { meta, exe });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// One-shot convenience: load + run.
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.load(name)?.run(inputs)
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn open_and_list() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::open(&dir).unwrap();
        assert!(rt.artifact_names().iter().any(|n| n.starts_with("dbb_gemm")));
        assert!(rt.artifact_names().contains(&"convnet5_b1"));
    }

    #[test]
    fn dbb_gemm_artifact_matches_golden() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::open(&dir).unwrap();
        let name = "dbb_gemm_m128_k256_n64_nnz4of8";
        let meta = rt.meta(name).expect("artifact in manifest").clone();
        let (m, k, n) = (128usize, 256usize, 64usize);
        let (kb, nnz, bz) = (k / 8, 4usize, 8usize);
        assert_eq!(meta.inputs[0].shape, vec![m, k]);

        // synthesize a DBB operand pair with the rust-side encoder
        let mut rng = crate::util::Rng::new(7);
        let a = crate::tensor::TensorI8::rand(&[m, k], &mut rng);
        let wd = crate::dbb::prune::prune_i8(
            &crate::tensor::TensorI8::rand(&[k, n], &mut rng),
            bz,
            nnz,
        );
        let w = crate::dbb::DbbMatrix::compress_with_bound(&wd, bz, nnz).unwrap();
        // pack (vals, idx) in the kernel's [KB, NNZ, N] layout
        let mut vals = vec![0i8; kb * nnz * n];
        let mut idx = vec![0i32; kb * nnz * n];
        for col in 0..n {
            for kbi in 0..kb {
                let blk = w.block(col, kbi);
                for (s, (v, p)) in blk.vals.iter().zip(blk.positions()).enumerate() {
                    vals[(kbi * nnz + s) * n + col] = *v;
                    idx[(kbi * nnz + s) * n + col] = p as i32;
                }
            }
        }
        let outs = rt
            .execute(
                name,
                &[
                    HostTensor::I8(a.data().to_vec()),
                    HostTensor::I8(vals),
                    HostTensor::I32(idx),
                ],
            )
            .unwrap();
        let got = outs[0].as_i32();
        let golden = crate::gemm::dense_i8(&a, &wd);
        assert_eq!(got, golden.data(), "XLA artifact vs rust golden GEMM");
    }

    #[test]
    fn convnet5_artifact_executes() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::open(&dir).unwrap();
        let meta = rt.meta("convnet5_b1").unwrap().clone();
        let n_in = meta.inputs[0].elems();
        let mut rng = crate::util::Rng::new(3);
        let x: Vec<f32> = (0..n_in).map(|_| rng.f32()).collect();
        let outs = rt.execute("convnet5_b1", &[HostTensor::F32(x)]).unwrap();
        let logits = outs[0].as_f32();
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        // non-degenerate: not all logits identical
        assert!(logits.iter().any(|v| (v - logits[0]).abs() > 1e-6));
    }

    #[test]
    fn executable_cache_hits() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::open(&dir).unwrap();
        let a = rt.load("convnet5_b1").unwrap();
        let b = rt.load("convnet5_b1").unwrap();
        assert!(std::rc::Rc::ptr_eq(&a, &b));
    }

    #[test]
    fn wrong_inputs_rejected() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut rt = Runtime::open(&dir).unwrap();
        let err = rt.execute("convnet5_b1", &[]).unwrap_err();
        assert!(err.to_string().contains("inputs"));
        let err2 = rt
            .execute("convnet5_b1", &[HostTensor::F32(vec![0.0; 3])])
            .unwrap_err();
        assert!(err2.to_string().contains("elems"), "{err2}");
    }
}
