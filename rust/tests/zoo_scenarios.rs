//! Zoo-scenario suite: the layer geometries the full serving zoo brings that
//! convnet5 never exercised, each driven end-to-end through the prepared
//! engine (prepare → profile → calibrate → staged == fused bit-exact), plus
//! the FC-only transformer block's persistence round trip and its serving
//! path through the coordinator registry.
//!
//! Shapes under test (see `models::zoo`):
//! * stride-2 **depthwise** conv (MobileNet's downsampling dw layers),
//! * the 7×7/stride-2/pad-3 **stem** conv (ResNet-50 conv1),
//! * 1×1 bottleneck convs with GEMM K straddling the
//!   [`ssta::gemm::micro::DBB_PACK_MAX_K`] pack guard (the packed microkernel
//!   ↔ scalar-CSC fallback boundary),
//! * an **FC-only** model (no conv sample at all, so the patch scratch is
//!   sized from `max_k == 0`).

use ssta::engine::{PreparedModel, SampleShape};
use ssta::gemm::conv::ConvShape;
use ssta::gemm::micro::DBB_PACK_MAX_K;
use ssta::models::{self, Layer, LayerKind, Model};
use ssta::tensor::TensorI8;
use ssta::util::{Parallelism, Rng};

/// Prepare + profile + calibrate at one encoding point — the exact lowering
/// `coordinator::prepare_served` runs once per model.
fn served(model: &Model, nnz: usize, bz: usize, par: Parallelism) -> PreparedModel {
    let mut pm = PreparedModel::prepare(model, nnz, bz, 42, par);
    pm.set_fused_epilogue(true);
    pm.profile(par);
    pm.calibrate(par);
    pm
}

/// The property the scenario sweep gates on: the fused i8→i8 chain and the
/// staged materialize-then-requant chain agree bit-for-bit on fresh inputs.
fn assert_staged_eq_fused(pm: &PreparedModel, input_shape: &[usize], par: Parallelism, tag: &str) {
    let mut rng = Rng::new(7);
    for i in 0..2 {
        let x = TensorI8::rand_sparse(input_shape, 0.5, &mut rng);
        let staged = pm.execute_staged(&x, par);
        let fused = pm.execute_fused(&x, par);
        assert_eq!(staged.output, fused.output, "{tag}: staged != fused, input {i}");
    }
}

fn dw(name: &str, hw: usize, c: usize, stride: usize) -> Layer {
    Layer {
        name: name.to_string(),
        kind: LayerKind::DepthwiseConv(ConvShape {
            h: hw,
            w: hw,
            c,
            kh: 3,
            kw: 3,
            oc: c,
            stride,
            pad: 1,
        }),
        prunable: false,
    }
}

fn pw(name: &str, hw: usize, c: usize, oc: usize) -> Layer {
    Layer {
        name: name.to_string(),
        kind: LayerKind::Conv(ConvShape { h: hw, w: hw, c, kh: 1, kw: 1, oc, stride: 1, pad: 0 }),
        prunable: true,
    }
}

#[test]
fn stride2_depthwise_pair_staged_eq_fused() {
    // a MobileNet downsampling separable pair at test scale: dw 3x3/s2
    // halves the map, the following pw consumes the halved map
    let m = Model {
        name: "dw-s2-pair",
        dataset: "synthetic",
        layers: vec![dw("dw_s2", 24, 8, 2), pw("pw", 12, 8, 16)],
    };
    let par = Parallelism::serial();
    let pm = served(&m, 3, 8, par);
    // the depthwise sample keeps the layer's stride geometry: 24/2 = 12
    match pm.layers()[0].sample {
        SampleShape::Conv(ss) => {
            assert_eq!(ss.stride, 2);
            assert_eq!((ss.oh(), ss.ow()), (12, 12), "s2 sample halves the map");
            assert_eq!(ss.c, 1, "depthwise samples one channel (K = kh·kw)");
        }
        SampleShape::Fc { .. } => panic!("depthwise layer sampled as FC"),
    }
    assert_staged_eq_fused(&pm, &[24, 24, 8], par, "dw-s2-pair");
}

#[test]
fn stem_7x7_stride2_staged_eq_fused() {
    // ResNet-50's conv1 geometry (7x7, stride 2, pad 3) at test scale,
    // followed by a 1x1/s2 shortcut-style bottleneck conv
    let c1 = ConvShape { h: 32, w: 32, c: 3, kh: 7, kw: 7, oc: 16, stride: 2, pad: 3 };
    let m = Model {
        name: "stem7x7",
        dataset: "synthetic",
        layers: vec![
            Layer { name: "conv1".into(), kind: LayerKind::Conv(c1), prunable: false },
            Layer {
                name: "shortcut".into(),
                kind: LayerKind::Conv(ConvShape {
                    h: 16,
                    w: 16,
                    c: 16,
                    kh: 1,
                    kw: 1,
                    oc: 32,
                    stride: 2,
                    pad: 0,
                }),
                prunable: true,
            },
        ],
    };
    let par = Parallelism::serial();
    let pm = served(&m, 3, 8, par);
    match pm.layers()[0].sample {
        SampleShape::Conv(ss) => {
            assert_eq!((ss.kh, ss.stride, ss.pad), (7, 2, 3));
            assert_eq!((ss.oh(), ss.ow()), (16, 16), "stem halves 32 -> 16");
        }
        SampleShape::Fc { .. } => panic!("stem sampled as FC"),
    }
    assert_staged_eq_fused(&pm, &[32, 32, 3], par, "stem7x7");
}

#[test]
fn bottleneck_1x1_k_across_pack_guard() {
    // a 1x1 bottleneck conv's GEMM K equals its channel count; straddle the
    // DBB_PACK_MAX_K pack guard so one side runs the packed microkernel and
    // the other the scalar CSC fallback — both must stay bit-exact with the
    // staged path
    let par = Parallelism::serial();
    for k in [DBB_PACK_MAX_K - 1, DBB_PACK_MAX_K, DBB_PACK_MAX_K + 1] {
        let m = Model {
            name: "bottleneck-k-guard",
            dataset: "synthetic",
            layers: vec![Layer {
                name: "conv1x1".into(),
                kind: LayerKind::Conv(ConvShape {
                    h: 4,
                    w: 4,
                    c: k,
                    kh: 1,
                    kw: 1,
                    oc: 8,
                    stride: 1,
                    pad: 0,
                }),
                prunable: true,
            }],
        };
        let pm = served(&m, 3, 8, par);
        assert_staged_eq_fused(&pm, &[4, 4, k], par, &format!("1x1 K={k}"));
    }
}

#[test]
fn transformer_block_fc_only_roundtrip() {
    // the FC-only zoo member: no conv layer anywhere, so the engine's patch
    // scratch is sized from max_k == 0 — prepare, persist, reload, and the
    // reloaded model's fused chain must match the original's staged chain
    let par = Parallelism::serial();
    let m = models::transformer_block();
    let pm = served(&m, 4, 8, par);
    for l in pm.layers() {
        assert!(
            matches!(l.sample, SampleShape::Fc { m: 1, .. }),
            "transformer layers are per-token FC GEMMs"
        );
    }
    let dir = std::env::temp_dir().join(format!("ssta-zoo-scen-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("transformer_nnz4_bz8.ssta");
    pm.save(&path).unwrap();
    let rt = PreparedModel::load(&path, par).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    // name interns back to the zoo's 'static str
    assert_eq!(rt.model_name(), "TransformerBlock");
    assert_eq!(rt.encoding(), pm.encoding());
    assert_eq!(rt.to_bytes(), pm.to_bytes(), "canonical re-serialization");
    let mut rng = Rng::new(11);
    for i in 0..3 {
        let x = TensorI8::rand_sparse(&[1, 768], 0.5, &mut rng);
        let staged = pm.execute_staged(&x, par);
        let fused = rt.execute_fused(&x, par);
        assert_eq!(staged.output, fused.output, "reload fused != staged, input {i}");
    }
    // the sequence dimension folds into GEMM M exactly like an image batch
    let seq: Vec<TensorI8> =
        (0..4).map(|_| TensorI8::rand_sparse(&[1, 768], 0.5, &mut rng)).collect();
    let folded = pm.execute_fused_batch(&seq, par);
    for (tok, out) in seq.iter().zip(&folded) {
        assert_eq!(pm.execute_fused(tok, par).output, *out, "batch fold per-token mismatch");
    }
}

#[test]
fn transformer_block_serves_through_registry() {
    // end-to-end through the engine-native coordinator: the zoo lookup, the
    // registry, and the batch flush must all accept the FC-only member
    use ssta::coordinator::registry::ModelSpec;
    use ssta::coordinator::{Config, Coordinator};
    let coord = Coordinator::start(Config {
        registry: vec![ModelSpec::new("TransformerBlock", 4, 8)],
        batch_sizes: vec![2, 1],
        max_wait: std::time::Duration::from_micros(200),
        parallelism: Parallelism::serial(),
        ..Config::default()
    })
    .expect("transformer block must be a servable zoo member");
    let h = coord.handle();
    let mut rng = Rng::new(3);
    let token: Vec<f32> = (0..768).map(|_| rng.f32()).collect();
    let r = h.infer_to("TransformerBlock", 1, token).expect("serve one token");
    assert!(!r.logits.is_empty(), "served logits must be non-empty");
    assert!(h.infer_to("NotAModel", 2, vec![0.0; 8]).is_err(), "unknown model rejected");
}

#[test]
fn mobilenet_and_resnet_zoo_members_flow_end_to_end() {
    // the real Table-I members with the new geometries: MobileNetV1 (13
    // dw/pw pairs incl. every stride-2 dw) and ResNet-50V1 (7x7 stem, 1x1
    // bottlenecks, 1x1/s2 shortcuts) — prepared, profiled, calibrated, and
    // staged == fused on the seed input
    let par = Parallelism::auto();
    for (model, nnz) in [(models::mobilenet_v1(), 4), (models::resnet50(), 3)] {
        let pm = served(&model, nnz, 8, par);
        let prof = pm.profiles().expect("profiled");
        assert_eq!(prof.len(), model.layers.len());
        assert!(
            prof.iter().all(|p| (0.0..=1.0).contains(&p.act_sparsity)),
            "{}: act sparsity in [0,1]",
            model.name
        );
        let staged = pm.execute_staged(pm.seed_input(), par);
        let fused = pm.execute_fused(pm.seed_input(), par);
        assert_eq!(staged.output, fused.output, "{}: staged != fused", model.name);
    }
}
