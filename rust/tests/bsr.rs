//! Integration suite for the BSR weight datapath — the format-polymorphic
//! second pipeline next to DBB. Exercised through the public API exactly as
//! the engine consumes it:
//!
//! * **pack/decompress** is lossless across block geometries (including
//!   partial edge blocks) and the coarse index really is
//!   `row_ptr`/`col_idx` only — no per-element bitmask;
//! * the **block-scheduler kernels** (tiled GEMM, gated, fused epilogue,
//!   streaming-IM2COL conv) are bit-exact with the dense oracle on the
//!   decompressed operand at every block size, sparsity extreme, and
//!   thread count — including M smaller than the pool;
//! * a **BSR-prepared engine** round-trips the v2 flat binary bit-exactly
//!   and rejects truncated or corrupted streams cleanly.

use ssta::dbb::prune::prune_bsr_i8;
use ssta::engine::{PreparedModel, PERSIST_MAGIC};
use ssta::gemm::{self, conv::ConvShape, fused, tiled};
use ssta::gemm::{BsrPacked, Epilogue, Requant, WeightFormat, ZeroGate};
use ssta::models::{Layer, LayerKind, Model};
use ssta::tensor::TensorI8;
use ssta::util::prop::{check, Config};
use ssta::util::{Parallelism, Rng};

/// The satellite's block-geometry sweep: powers of two plus a non-dividing
/// size so edge blocks are partial in both dimensions.
const BLOCK_SIZES: [usize; 4] = [4, 8, 14, 16];

/// A block-pruned operand at one of the three sparsity extremes the suite
/// pins: dense (every block survives), half the blocks, or fully zero.
fn pruned_operand(k: usize, n: usize, bz: usize, sparsity: usize, rng: &mut Rng) -> TensorI8 {
    let w = TensorI8::rand(&[k, n], rng);
    let nbc = n.div_ceil(bz);
    match sparsity {
        0 => w,
        1 => prune_bsr_i8(&w, bz, bz, nbc.div_ceil(2)),
        _ => TensorI8::zeros(&[k, n]),
    }
}

#[test]
fn pack_decompress_is_lossless_and_index_is_coarse() {
    check(Config::default().cases(64), |rng| {
        let bz_r = BLOCK_SIZES[rng.below(4)];
        let bz_c = BLOCK_SIZES[rng.below(4)];
        let k = rng.below(90) + 1; // rarely a multiple of bz → edge blocks
        let n = rng.below(60) + 1;
        let sparsity = rng.below(3);
        let w = pruned_operand(k, n, bz_r.min(bz_c), sparsity, rng);
        let p = BsrPacked::pack(&w, bz_r, bz_c);
        assert_eq!(p.decompress().data(), w.data(), "k={k} n={n} bz={bz_r}x{bz_c}");
        // the defining contrast with DBB: the index is one row_ptr entry
        // per block row + one col_idx per surviving block, nothing per
        // element
        assert_eq!(p.block_rows(), k.div_ceil(bz_r));
        assert_eq!(p.block_cols(), n.div_ceil(bz_c));
        assert_eq!(p.index_bytes(), 4 * (p.block_rows() + 1) + 2 * p.stored_blocks());
        // col_idx strictly ascending within each block row
        let (rp, ci) = (p.row_ptr(), p.col_idx());
        for br in 0..p.block_rows() {
            let row = &ci[rp[br]..rp[br + 1]];
            assert!(row.windows(2).all(|w| w[0] < w[1]), "row {br}: {row:?}");
        }
        if sparsity == 2 {
            assert_eq!(p.stored_blocks(), 0, "all-zero matrix stores no blocks");
        }
    });
}

#[test]
fn tiled_bsr_matches_dense_oracle_across_geometry_and_threads() {
    check(Config::default().cases(64), |rng| {
        let bz = BLOCK_SIZES[rng.below(4)];
        let m = rng.below(48) + 1;
        let k = rng.below(90) + 1;
        let n = rng.below(40) + 1;
        let sparsity = rng.below(3);
        let threads = [1usize, 2, 5, 8][rng.below(4)];
        let a = TensorI8::rand_sparse(&[m, k], 0.5, rng);
        let w = pruned_operand(k, n, bz, sparsity, rng);
        let p = BsrPacked::pack(&w, bz, bz);
        let par = Parallelism::threads(threads);
        let want = gemm::dense_i8(&a, &p.decompress());
        let tag = format!("m={m} k={k} n={n} bz={bz} sp={sparsity} threads={threads}");
        assert_eq!(tiled::bsr_i8_packed(&a, &p, par).data(), want.data(), "{tag}");
        for gate in [ZeroGate::Off, ZeroGate::On, ZeroGate::Auto] {
            assert_eq!(
                tiled::bsr_i8_packed_gated(&a, &p, par, gate).data(),
                want.data(),
                "{tag} gate={gate:?}"
            );
        }
    });
}

#[test]
fn m_smaller_than_thread_count() {
    // every M in 1..8 against an 8-thread pool — the row partition
    // degenerates to one row per worker with idle workers left over
    let mut rng = Rng::new(23);
    let par = Parallelism::threads(8);
    for m in 1..8usize {
        let a = TensorI8::rand(&[m, 44], &mut rng);
        let w = pruned_operand(44, 12, 8, 1, &mut rng);
        let p = BsrPacked::pack(&w, 8, 8);
        assert_eq!(
            tiled::bsr_i8_packed(&a, &p, par).data(),
            gemm::dense_i8(&a, &p.decompress()).data(),
            "m={m}"
        );
    }
}

#[test]
fn fused_epilogue_matches_dense_epilogue_path() {
    check(Config::default().cases(32), |rng| {
        let bz = BLOCK_SIZES[rng.below(4)];
        let m = rng.below(32) + 1;
        let k = rng.below(64) + 1;
        let n = rng.below(24) + 1;
        let a = TensorI8::rand_sparse(&[m, k], 0.5, rng);
        let w = pruned_operand(k, n, bz, 1, rng);
        let p = BsrPacked::pack(&w, bz, bz);
        let par = Parallelism::threads(rng.below(4) + 1);
        let ep = Epilogue::new(Requant::Global(rng.below(8) as u32), rng.below(2) == 0);
        for gate in [ZeroGate::Off, ZeroGate::On] {
            assert_eq!(
                tiled::bsr_i8_packed_ep(&a, &p, par, gate, &ep).data(),
                tiled::dense_i8_ep(&a, &p.decompress(), par, gate, &ep).data(),
                "m={m} k={k} n={n} bz={bz} gate={gate:?}"
            );
        }
    });
}

#[test]
fn fused_conv_matches_dense_conv_on_decompressed_weights() {
    // c·kh·kw deliberately not a multiple of the block size → the BSR
    // operand ends in partial edge blocks along K
    let s = ConvShape { h: 9, w: 9, c: 3, kh: 3, kw: 3, oc: 10, stride: 1, pad: 1 };
    let mut rng = Rng::new(31);
    for bz in BLOCK_SIZES {
        for sparsity in 0..3usize {
            let w = pruned_operand(s.gemm_k(), s.oc, bz, sparsity, &mut rng);
            let p = BsrPacked::pack(&w, bz, bz);
            for threads in [1usize, 4] {
                let par = Parallelism::threads(threads);
                let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], 0.5, &mut rng);
                let want = fused::conv2d_i8(&x, &p.decompress(), &s, par);
                assert_eq!(
                    fused::conv2d_bsr_i8_packed(&x, &p, &s, par).data(),
                    want.data(),
                    "bz={bz} sp={sparsity} threads={threads}"
                );
                for gate in [ZeroGate::Off, ZeroGate::On, ZeroGate::Auto] {
                    assert_eq!(
                        fused::conv2d_bsr_i8_packed_gated(&x, &p, &s, par, gate).data(),
                        want.data(),
                        "bz={bz} sp={sparsity} threads={threads} gate={gate:?}"
                    );
                }
            }
        }
    }
}

/// A small conv+FC model with a prunable conv — enough to give the engine
/// a real BSR operand next to a dense-fallback layer.
fn bsr_model() -> Model {
    let c1 = ConvShape { h: 10, w: 10, c: 3, kh: 3, kw: 3, oc: 8, stride: 1, pad: 1 };
    let c2 = ConvShape { h: 10, w: 10, c: 8, kh: 3, kw: 3, oc: 16, stride: 2, pad: 1 };
    Model {
        name: "bsr-int",
        dataset: "synthetic",
        layers: vec![
            Layer { name: "conv1".into(), kind: LayerKind::Conv(c1), prunable: false },
            Layer { name: "conv2".into(), kind: LayerKind::Conv(c2), prunable: true },
            Layer { name: "fc".into(), kind: LayerKind::Fc(5 * 5 * 16, 10), prunable: true },
        ],
    }
}

#[test]
fn bsr_engine_roundtrips_flat_binary_bit_exactly() {
    let par = Parallelism::serial();
    let mut pm = PreparedModel::prepare_format(&bsr_model(), 2, 8, 7, par, WeightFormat::Bsr);
    pm.set_fused_epilogue(true);
    pm.profile(par);
    pm.calibrate(par);
    assert_eq!(pm.weight_format(), WeightFormat::Bsr);

    let bytes = pm.to_bytes();
    assert_eq!(&bytes[..8], PERSIST_MAGIC, "BSR models persist as v2");
    let rt = PreparedModel::from_bytes(&bytes, par).unwrap();
    assert_eq!(rt.weight_format(), WeightFormat::Bsr);
    assert_eq!(rt.operand_bytes(), pm.operand_bytes());
    let mut rng = Rng::new(3);
    for _ in 0..3 {
        let x = TensorI8::rand_sparse(&[10, 10, 3], 0.5, &mut rng);
        assert_eq!(rt.execute(&x, par).output, pm.execute(&x, par).output);
        assert_eq!(rt.execute_fused(&x, par).output, pm.execute_fused(&x, par).output);
    }
    assert_eq!(rt.to_bytes(), bytes, "canonical re-serialization");
}

#[test]
fn bsr_stream_truncation_and_corruption_are_clean_errors() {
    let par = Parallelism::serial();
    let pm = PreparedModel::prepare_format(&bsr_model(), 2, 8, 7, par, WeightFormat::Bsr);
    let bytes = pm.to_bytes();
    for i in 0..16 {
        let cut = i * bytes.len() / 16;
        assert!(
            PreparedModel::from_bytes(&bytes[..cut], par).is_err(),
            "truncation at {cut}/{} must fail cleanly",
            bytes.len()
        );
    }
    // the trailing FNV-1a checksum catches any flipped bit in the body —
    // including inside the BSR row_ptr/col_idx/block payload
    for &pos in &[0usize, 9, bytes.len() / 3, bytes.len() / 2, bytes.len() - 3] {
        let mut bad = bytes.clone();
        bad[pos] ^= 0x20;
        assert!(
            PreparedModel::from_bytes(&bad, par).is_err(),
            "bit flip at {pos}/{} must fail cleanly",
            bytes.len()
        );
    }
}
