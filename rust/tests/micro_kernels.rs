//! Property suite for the SIMD microkernel dispatch layer
//! (`ssta::gemm::micro`): every driver that routes through the
//! microkernels — `gemm::{dense_i8, dense_i8_gated, dbb_i8_packed,
//! dbb_i8_packed_gated, adbb_dense_i8}`, their `tiled::*` pools and the
//! `fused::conv2d_*` engine — must be **bit-exact** with the forced-Scalar
//! oracle on every ISA the host supports, across remainder shapes (N and K
//! off the 16-lane / 256-deep block boundaries), DBB bounds `nnz 1..=bz`
//! for `bz ∈ {4, 8, 16}`, operand sparsity 0 / 0.5 / 1, partial MR row
//! blocks, the `K > DBB_PACK_MAX_K` scalar fallback, gated and encoded
//! variants, worker-pool widths, and pinned pools.
//!
//! The ISA override (`micro::force_isa`) is process-global, so every test
//! that flips it serializes on one mutex and restores the override through
//! a drop guard. Tests that do *not* take the lock are still safe to run
//! concurrently: every ISA is bit-exact, so a transient switch cannot
//! change any value-equality assertion.

use std::sync::Mutex;

use ssta::dbb::DbbMatrix;
use ssta::gemm;
use ssta::gemm::conv::{conv2d_direct, weights_to_gemm, ConvShape};
use ssta::gemm::micro::{self, Isa};
use ssta::gemm::{fused, tiled, ActDbb, DbbPacked, ZeroGate};
use ssta::tensor::TensorI8;
use ssta::util::prop::{check, Config};
use ssta::util::{Parallelism, Rng};

static ISA_LOCK: Mutex<()> = Mutex::new(());

/// Holds the process-global ISA lock and restores the default dispatch
/// (no override) on drop — even when the assertion inside panics, so a
/// failing case never leaks a forced ISA into the next test.
struct IsaGuard(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

impl IsaGuard {
    fn acquire() -> IsaGuard {
        IsaGuard(ISA_LOCK.lock().unwrap_or_else(|e| e.into_inner()))
    }
}

impl Drop for IsaGuard {
    fn drop(&mut self) {
        micro::force_isa(None);
    }
}

/// Evaluate `eval` under forced-Scalar (the oracle) and then under every
/// ISA the host supports, asserting each result list is bit-identical.
fn exact_on_every_isa<F: Fn() -> Vec<Vec<i32>>>(tag: &str, eval: F) {
    let _guard = IsaGuard::acquire();
    micro::force_isa(Some(Isa::Scalar));
    let want = eval();
    for isa in micro::available_isas() {
        micro::force_isa(Some(isa));
        let got = eval();
        assert_eq!(got.len(), want.len(), "{tag}: variant count under {isa}");
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "{tag}: variant #{i} diverges from scalar under {isa}");
        }
    }
}

/// Case-count that stays overridable by `SSTA_PROP_CASES` (the miri job
/// shrinks the grid through it; an explicit `.cases(n)` would mask it).
fn cfg(n: u32) -> Config {
    if std::env::var("SSTA_PROP_CASES").is_ok() {
        Config::default()
    } else {
        Config::default().cases(n)
    }
}

const SPARSITIES: [f32; 3] = [0.0, 0.5, 1.0];

// Deterministic remainder grids: N crossing the 16-lane NR boundary, K
// crossing the 256-deep KC tile boundary. Shrunk under miri (the
// interpreter pays per executed op, not per wall-clock).
#[cfg(not(miri))]
const NS: &[usize] = &[1, 2, 3, 15, 16, 17, 31, 32, 33];
#[cfg(miri)]
const NS: &[usize] = &[1, 15, 17];
#[cfg(not(miri))]
const KS: &[usize] = &[1, 255, 256, 257, 300];
#[cfg(miri)]
const KS: &[usize] = &[1, 17, 40];

#[test]
fn dense_exact_across_remainder_shapes() {
    let mut rng = Rng::new(0x51D0_0001);
    for &k in KS {
        for &n in NS {
            for m in [1usize, 5] {
                let a = TensorI8::rand_sparse(&[m, k], 0.4, &mut rng);
                let w = TensorI8::rand(&[k, n], &mut rng);
                exact_on_every_isa(&format!("dense m={m} k={k} n={n}"), || {
                    vec![
                        gemm::dense_i8(&a, &w).into_vec(),
                        gemm::dense_i8_gated(&a, &w, ZeroGate::On).into_vec(),
                        gemm::dense_i8_gated(&a, &w, ZeroGate::Off).into_vec(),
                    ]
                });
            }
        }
    }
}

#[test]
fn dense_prop_exact_through_tiled_pools() {
    check(cfg(24), |rng| {
        let m = rng.below(24) + 1;
        let k = rng.below(300) + 1;
        let n = rng.below(40) + 1;
        let threads = rng.below(6) + 1;
        let p_zero = SPARSITIES[rng.below(3)];
        let a = TensorI8::rand_sparse(&[m, k], p_zero, rng);
        let w = TensorI8::rand(&[k, n], rng);
        let par = Parallelism::threads(threads);
        exact_on_every_isa(&format!("tiled dense m={m} k={k} n={n} t={threads}"), || {
            vec![
                tiled::dense_i8(&a, &w, par).into_vec(),
                tiled::dense_i8_gated(&a, &w, par, ZeroGate::On).into_vec(),
            ]
        });
    });
}

#[test]
fn dbb_exact_across_nnz_bz_sparsity_partial_blocks() {
    let mut rng = Rng::new(0x51D0_0002);
    let k = 48usize;
    let n = 17usize;
    for bz in [4usize, 8, 16] {
        for nnz in 1..=bz {
            for p_zero in SPARSITIES {
                // m ∈ {1, 7, 9}: below, just-below, and just-past one MR=8
                // row block — the pack-transpose padding lanes and the
                // partial-block scatter both get exercised.
                for m in [1usize, 7, 9] {
                    let a = TensorI8::rand_sparse(&[m, k], p_zero, &mut rng);
                    let wd = TensorI8::rand(&[k, n], &mut rng);
                    let w = DbbPacked::pack(&DbbMatrix::compress_topk(&wd, bz, nnz).unwrap());
                    let tag = format!("dbb m={m} bz={bz} nnz={nnz} p={p_zero}");
                    exact_on_every_isa(&tag, || {
                        vec![
                            gemm::dbb_i8_packed(&a, &w).into_vec(),
                            gemm::dbb_i8_packed_gated(&a, &w, ZeroGate::On).into_vec(),
                            tiled::dbb_i8_packed(&a, &w, Parallelism::threads(3)).into_vec(),
                        ]
                    });
                }
            }
        }
    }
}

#[test]
#[cfg_attr(miri, ignore = "K beyond the pack cap is a plain-size stress case")]
fn dbb_k_beyond_pack_limit_falls_back_exact() {
    // K past DBB_PACK_MAX_K routes every ISA to the scalar CSC walk —
    // results must still match the forced-Scalar oracle bit for bit.
    let mut rng = Rng::new(0x51D0_0003);
    let k = micro::DBB_PACK_MAX_K + 8;
    let (m, n) = (3usize, 4usize);
    let a = TensorI8::rand_sparse(&[m, k], 0.5, &mut rng);
    let wd = TensorI8::rand_sparse(&[k, n], 0.6, &mut rng);
    let w = DbbPacked::pack(&DbbMatrix::compress(&wd, 8).unwrap());
    exact_on_every_isa("dbb k>DBB_PACK_MAX_K", || {
        vec![
            gemm::dbb_i8_packed(&a, &w).into_vec(),
            gemm::dbb_i8_packed_gated(&a, &w, ZeroGate::On).into_vec(),
        ]
    });
}

#[test]
fn encoded_activation_paths_exact() {
    check(cfg(24), |rng| {
        let m = rng.below(20) + 1;
        let k = rng.below(96) + 1;
        let n = rng.below(24) + 1;
        let bz = [4usize, 8, 16][rng.below(3)];
        let p_zero = SPARSITIES[rng.below(3)];
        let a = TensorI8::rand_sparse(&[m, k], p_zero, rng);
        let wd = TensorI8::rand(&[k, n], rng);
        let w = DbbPacked::pack(&DbbMatrix::compress_topk(&wd, bz, bz.min(3)).unwrap());
        let enc = ActDbb::encode(&a, bz);
        let par = Parallelism::threads(rng.below(4) + 1);
        exact_on_every_isa(&format!("adbb m={m} k={k} n={n} bz={bz}"), || {
            vec![
                // dense-W joint kernel: micro-dispatched
                gemm::adbb_dense_i8(&enc, &wd).into_vec(),
                tiled::adbb_dense_i8(&enc, &wd, par).into_vec(),
                // merge-join kernel: scalar on every ISA, still covered so
                // a future vectorization inherits the same oracle
                gemm::adbb_i8_packed(&enc, &w).into_vec(),
            ]
        });
    });
}

fn rand_conv_shape(rng: &mut Rng) -> ConvShape {
    let kh = [1usize, 3, 5][rng.below(3)];
    let stride = rng.below(2) + 1;
    ConvShape {
        h: kh + rng.below(6) + stride,
        w: kh + rng.below(6) + stride,
        c: rng.below(6) + 1,
        kh,
        kw: kh,
        oc: rng.below(20) + 1,
        stride,
        pad: rng.below(kh.div_ceil(2)),
    }
}

#[test]
fn fused_conv_exact_across_isas() {
    check(cfg(16), |rng| {
        let s = rand_conv_shape(rng);
        let threads = rng.below(4) + 1;
        let p_zero = SPARSITIES[rng.below(3)];
        let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], p_zero, rng);
        let w4 = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], rng);
        let wg = weights_to_gemm(&w4, &s);
        let bz = [4usize, 8][rng.below(2)];
        let wp = DbbPacked::pack(&DbbMatrix::compress_topk(&wg, bz, bz / 2 + 1).unwrap());
        let par = Parallelism::threads(threads);
        let want = conv2d_direct(&x, &w4, &s);
        exact_on_every_isa(&format!("conv {s:?} t={threads} p={p_zero}"), || {
            let got = vec![
                fused::conv2d_i8(&x, &w4, &s, par).into_vec(),
                fused::conv2d_i8_gated(&x, &w4, &s, par, ZeroGate::On).into_vec(),
                fused::conv2d_i8_encoded(&x, &w4, &s, par).into_vec(),
                fused::conv2d_dbb_i8_packed(&x, &wp, &s, par).into_vec(),
                fused::conv2d_dbb_i8_packed_gated(&x, &wp, &s, par, ZeroGate::On).into_vec(),
                fused::conv2d_dbb_i8_packed_encoded(&x, &wp, &s, par).into_vec(),
            ];
            // the dense variants must also equal the direct-conv oracle on
            // every ISA, not just agree with their own scalar runs
            assert_eq!(got[0], want.data(), "conv2d_i8 vs direct {s:?}");
            got
        });
    });
}

#[test]
fn pinned_pools_stay_exact() {
    // pinning is scheduling-only: with_pin(true) must reproduce the
    // unpinned result bit for bit on every ISA
    let mut rng = Rng::new(0x51D0_0004);
    let a = TensorI8::rand_sparse(&[19, 120], 0.5, &mut rng);
    let w = TensorI8::rand(&[120, 33], &mut rng);
    let s = ConvShape { h: 8, w: 8, c: 3, kh: 3, kw: 3, oc: 9, stride: 1, pad: 1 };
    let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], 0.5, &mut rng);
    let w4 = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], &mut rng);
    let plain = Parallelism::threads(4);
    let pinned = plain.with_pin(true);
    exact_on_every_isa("pinned pools", || {
        let g = tiled::dense_i8(&a, &w, pinned);
        assert_eq!(g.data(), tiled::dense_i8(&a, &w, plain).data(), "gemm pin");
        let c = fused::conv2d_i8(&x, &w4, &s, pinned);
        assert_eq!(c.data(), fused::conv2d_i8(&x, &w4, &s, plain).data(), "conv pin");
        vec![g.into_vec(), c.into_vec()]
    });
}

#[test]
fn env_forced_isa_is_honored() {
    // Pins the CI kernel-matrix contract: with no runtime override, the
    // default dispatch honors SSTA_FORCE_ISA when it names a supported ISA
    // (unsupported names clamp down by rank and still dispatch).
    let _guard = IsaGuard::acquire();
    micro::force_isa(None);
    let active = micro::active_isa();
    assert!(micro::supported(active), "active ISA must be supported");
    if let Ok(name) = std::env::var("SSTA_FORCE_ISA") {
        if !name.trim().is_empty() {
            let asked = Isa::from_name(&name).expect("SSTA_FORCE_ISA names a known ISA");
            if micro::supported(asked) {
                assert_eq!(active, asked, "env-forced ISA must win the dispatch");
            }
        }
    }
    // and the runtime override outranks the environment
    for isa in micro::available_isas() {
        micro::force_isa(Some(isa));
        assert_eq!(micro::active_isa(), isa);
    }
}
