//! Integration: the complete software→hardware pipeline in one test —
//! train a CNN with DBB pruning, quantize, export compressed weights,
//! run the GEMMs bit-exactly on the array simulator, and price the run
//! with the power model. Every module boundary in the repo is crossed.

use ssta::arch::Design;
use ssta::dbb::analyze;
use ssta::gemm;
use ssta::power;
use ssta::sim::detailed::simulate_gemm;
use ssta::tensor::TensorI8;
use ssta::train::{self, data, quant, zoo, TrainConfig};
use ssta::util::Rng;

#[test]
fn train_prune_quantize_simulate_price() {
    let (tr, te) = data::synth_mnist_split(400, 100, 77);
    let cfg = TrainConfig {
        baseline_epochs: 2,
        prune_epochs: 2,
        finetune_epochs: 1,
        ..TrainConfig::default()
    };
    let (bz, nnz) = (8usize, 3usize);

    // ---- train + prune + quantize (phases of train::three_phase,
    //      unrolled so we keep the model) ----
    let mut model = zoo::lenet5(&mut Rng::new(9));
    let mut rng = Rng::new(cfg.seed);
    for _ in 0..cfg.baseline_epochs {
        train::train_epoch(&mut model.net, &tr, &cfg, &mut rng, None);
    }
    let mut sched = ssta::train::pruning::DbbPruneSchedule::new(bz, nnz, cfg.prune_epochs);
    for e in 0..cfg.prune_epochs {
        sched.prune_epoch(&mut model.net, &model.prunable, e);
        train::train_epoch(&mut model.net, &tr, &cfg, &mut rng, Some(&sched));
    }
    sched.prune_epoch(&mut model.net, &model.prunable, cfg.prune_epochs);
    quant::quantize_network(&mut model.net);
    sched.enforce(&mut model.net);
    let acc = train::evaluate(&mut model.net, &te);
    assert!(acc > 0.4, "pruned INT8 model should still classify: {acc}");

    // ---- export the fc1 weights (prunable, biggest layer) ----
    let prunable = model.prunable.clone();
    let weights = model.net.gemm_weights();
    let (name, w) = weights
        .into_iter()
        .zip(&prunable)
        .filter(|((n, _), &p)| p && n.starts_with("fc"))
        .map(|(nw, _)| nw)
        .next()
        .expect("an fc prunable layer");
    let (dbb, _scale) = quant::export_dbb(w, bz);
    assert!(dbb.max_block_nnz() <= nnz, "{name} violates the trained bound");
    let summary = analyze::summarize(&dbb);
    assert!(
        summary.elem_sparsity_pct > 50.0,
        "exported sparsity {}%",
        summary.elem_sparsity_pct
    );

    // ---- run the layer's GEMM on the simulated STA-VDBB, bit-exact ----
    let mut arng = Rng::new(5);
    let a = TensorI8::rand_sparse(&[16, dbb.k], 0.5, &mut arng);
    let design = Design::paper_optimal();
    let result = simulate_gemm(&design, &a, &dbb, 1.0);
    let golden = gemm::dense_i8(&a, &dbb.decompress());
    assert_eq!(result.output.data(), golden.data(), "simulator bit-exact on trained weights");

    // ---- price it ----
    let p = power::power(&design, &result.timing.events);
    assert!(p.total_mw() > 0.0);
    let tw = power::effective_tops_per_w(&design, &result.timing.events, result.timing.dense_macs);
    assert!(tw > 1.0, "trained-layer TOPS/W {tw}");
}

#[test]
fn vdbb_speedup_on_trained_weights_matches_bound() {
    // the *trained* weight matrices must get the same cycle scaling the
    // synthetic sweeps promise: occupancy == the layer's encoded bound
    let (tr, _te) = data::synth_mnist_split(300, 50, 88);
    let cfg = TrainConfig {
        baseline_epochs: 1,
        prune_epochs: 2,
        finetune_epochs: 0,
        ..TrainConfig::default()
    };
    let design = Design::parse("2x8x4_2x2_VDBB").unwrap();
    let mut cycles_by_bound = Vec::new();
    for nnz in [2usize, 4, 8] {
        let mut model = zoo::lenet5(&mut Rng::new(11));
        let mut rng = Rng::new(cfg.seed);
        train::train_epoch(&mut model.net, &tr, &cfg, &mut rng, None);
        let mut sched = ssta::train::pruning::DbbPruneSchedule::new(8, nnz, cfg.prune_epochs);
        sched.prune_epoch(&mut model.net, &model.prunable, cfg.prune_epochs);
        quant::quantize_network(&mut model.net);
        sched.enforce(&mut model.net);

        let prunable = model.prunable.clone();
        let weights = model.net.gemm_weights();
        let (_, w) = weights
            .into_iter()
            .zip(&prunable)
            .filter(|((n, _), &p)| p && n.starts_with("fc"))
            .map(|(nw, _)| nw)
            .next()
            .unwrap();
        let mut dbb = quant::export_dbb(w, 8).0;
        // encode at the schedule bound even if training left some blocks
        // under-full (hardware streams at the configured bound)
        dbb.bound = nnz;
        let mut arng = Rng::new(3);
        let a = TensorI8::rand(&[8, dbb.k], &mut arng);
        let r = simulate_gemm(&design, &a, &dbb, 1.0);
        cycles_by_bound.push(r.timing.events.cycles);
    }
    // cycles scale ≈ bound (2:4:8)
    let (c2, c4, c8) = (
        cycles_by_bound[0] as f64,
        cycles_by_bound[1] as f64,
        cycles_by_bound[2] as f64,
    );
    assert!((c4 / c2 - 2.0).abs() < 0.25, "c4/c2 = {}", c4 / c2);
    assert!((c8 / c4 - 2.0).abs() < 0.25, "c8/c4 = {}", c8 / c4);
}
