//! Property suite for the activation-side DBB pipeline
//! (`ssta::gemm::ActDbb` + the joint A-DBB kernels + the engine's
//! three-way `ActPolicy`): encoded-A must be **bit-exact** with dense-A
//! under every weight encoding (`nnz 1..=bz`, `bz ∈ {4, 8, 16}`, dense
//! fallback), every operand sparsity (0.0 / 0.5 / 1.0, including all-zero
//! rows), every worker-pool width (including `M < threads`), and through
//! the fused conv engine (whose chunk encoder must compress the IM2COL
//! padding zeros losslessly); `PreparedModel::execute` must resolve the
//! three-way policy per layer from its recorded profile and stay bit-exact
//! under every policy.

use ssta::dbb::DbbMatrix;
use ssta::engine::PreparedModel;
use ssta::gemm;
use ssta::gemm::conv::ConvShape;
use ssta::gemm::{fused, tiled, ActDbb, ActPolicy, DbbPacked};
use ssta::models;
use ssta::tensor::TensorI8;
use ssta::util::prop::{check, Config};
use ssta::util::{Parallelism, Rng};

const SPARSITIES: [f32; 3] = [0.0, 0.5, 1.0];

#[test]
fn encode_is_lossless() {
    check(Config::default().cases(96), |rng| {
        let m = rng.below(24) + 1;
        let k = rng.below(64) + 1;
        let bz = [4usize, 8, 16][rng.below(3)];
        let p_zero = SPARSITIES[rng.below(3)];
        let a = TensorI8::rand_sparse(&[m, k], p_zero, rng);
        let enc = ActDbb::encode(&a, bz);
        let mut back = TensorI8::zeros(&[m, k]);
        for row in 0..m {
            for &(kk, v) in &enc.entries()[enc.row_ptr()[row]..enc.row_ptr()[row + 1]] {
                back.set(&[row, kk as usize], v as i8);
            }
        }
        assert_eq!(back.data(), a.data(), "m={m} k={k} bz={bz} p={p_zero}");
        assert_eq!(enc.total_nnz(), a.data().iter().filter(|&&v| v != 0).count());
        assert!((enc.sparsity() - a.sparsity()).abs() < 1e-12);
        assert!(enc.bound >= 1 && enc.bound <= bz);
        // the fixed-rate stream never exceeds values + full index overhead
        assert!(enc.stream_bytes() <= m * enc.kblocks() * (bz + bz.div_ceil(8)));
    });
}

#[test]
fn encoded_a_bit_exact_across_nnz_bz_sparsity_threads() {
    // the headline property: encoded-A vs dense-A across the full grid —
    // weight bounds 1..=bz, bz ∈ {4,8,16}, A sparsity 0/0.5/1, thread
    // counts 1..8 including M < threads
    check(Config::default().cases(96), |rng| {
        let m = rng.below(32) + 1;
        let k = rng.below(64) + 1;
        let n = rng.below(20) + 1;
        let bz = [4usize, 8, 16][rng.below(3)];
        let nnz = rng.below(bz) + 1;
        let threads = rng.below(8) + 1;
        let p_zero = SPARSITIES[rng.below(3)];
        let a = TensorI8::rand_sparse(&[m, k], p_zero, rng);
        let wd = TensorI8::rand(&[k, n], rng);
        let enc = ActDbb::encode(&a, bz);
        let par = Parallelism::threads(threads);

        // dense-fallback weights: joint kernel vs the dense oracle
        let want_dense = gemm::dense_i8(&a, &wd);
        assert_eq!(
            gemm::adbb_dense_i8(&enc, &wd).data(),
            want_dense.data(),
            "serial dense m={m} k={k} n={n} bz={bz} p={p_zero}"
        );
        assert_eq!(
            tiled::adbb_dense_i8(&enc, &wd, par).data(),
            want_dense.data(),
            "tiled dense m={m} k={k} n={n} bz={bz} threads={threads} p={p_zero}"
        );

        // DBB weights: joint kernel vs the per-call-decode oracle
        let w = DbbMatrix::compress_topk(&wd, bz, nnz).unwrap();
        let packed = DbbPacked::pack(&w);
        let want_dbb = gemm::dbb_i8(&a, &w);
        assert_eq!(
            gemm::adbb_i8_packed(&enc, &packed).data(),
            want_dbb.data(),
            "serial dbb m={m} k={k} n={n} bz={bz} nnz={nnz} p={p_zero}"
        );
        assert_eq!(
            tiled::adbb_i8_packed(&enc, &packed, par).data(),
            want_dbb.data(),
            "tiled dbb m={m} k={k} n={n} bz={bz} nnz={nnz} threads={threads} p={p_zero}"
        );
    });
}

#[test]
fn all_zero_and_single_row_operands() {
    // the degenerate corners: an all-zero A encodes to an empty stream and
    // must still produce exact zeros; M = 1 with many threads must not split
    let mut rng = Rng::new(3);
    let wd = TensorI8::rand(&[24, 7], &mut rng);
    let enc0 = ActDbb::encode(&TensorI8::zeros(&[5, 24]), 8);
    assert_eq!(enc0.total_nnz(), 0);
    assert!(gemm::adbb_dense_i8(&enc0, &wd).data().iter().all(|&v| v == 0));
    let w = DbbMatrix::compress_topk(&wd, 8, 3).unwrap();
    let packed = DbbPacked::pack(&w);
    assert!(tiled::adbb_i8_packed(&enc0, &packed, Parallelism::threads(8))
        .data()
        .iter()
        .all(|&v| v == 0));

    let a1 = TensorI8::rand(&[1, 24], &mut rng);
    let e1 = ActDbb::encode(&a1, 8);
    assert_eq!(
        tiled::adbb_i8_packed(&e1, &packed, Parallelism::threads(8)).data(),
        gemm::dbb_i8(&a1, &w).data()
    );
}

#[test]
fn fused_encoded_conv_compresses_padding_zeros_bit_exactly() {
    // padded convs generate IM2COL rows whose padding zeros the chunk
    // encoder must drop without changing a bit of the result
    check(Config::default().cases(64), |rng| {
        let kh = [1usize, 3, 5][rng.below(3)];
        let stride = rng.below(2) + 1;
        let s = ConvShape {
            h: kh + rng.below(6) + stride,
            w: kh + rng.below(6) + stride,
            c: rng.below(8) + 1,
            kh,
            kw: kh,
            oc: rng.below(8) + 1,
            stride,
            // bias toward real padding so the padded-row case is exercised
            pad: kh / 2,
        };
        let threads = rng.below(8) + 1;
        let p_zero = SPARSITIES[rng.below(3)];
        let par = Parallelism::threads(threads);
        let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], p_zero, rng);
        let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], rng);
        assert_eq!(
            fused::conv2d_i8_encoded(&x, &w, &s, par).data(),
            fused::conv2d_i8(&x, &w, &s, par).data(),
            "dense conv shape={s:?} threads={threads} p={p_zero}"
        );
        let enc = DbbMatrix::compress_topk(
            &TensorI8::rand(&[s.gemm_k(), s.oc], rng),
            8,
            rng.below(8) + 1,
        )
        .unwrap();
        let packed = DbbPacked::pack(&enc);
        assert_eq!(
            fused::conv2d_dbb_i8_packed_encoded(&x, &packed, &s, par).data(),
            fused::conv2d_dbb_i8_packed(&x, &packed, &s, par).data(),
            "dbb conv shape={s:?} threads={threads} p={p_zero}"
        );
    });
}

#[test]
fn execute_resolves_three_way_policy_from_recorded_profile() {
    let m = models::convnet5();
    let mut pm = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::threads(3));
    let par = Parallelism::threads(3);
    pm.profile(par);
    let measured = pm.measured_act_sparsity().expect("profile ran").to_vec();

    let off = pm.execute_policy(pm.seed_input(), par, ActPolicy::Off);
    let gate = pm.execute_policy(pm.seed_input(), par, ActPolicy::Gate);
    let enc = pm.execute_policy(pm.seed_input(), par, ActPolicy::Encode);
    let auto = pm.execute_policy(pm.seed_input(), par, ActPolicy::Auto);
    assert_eq!(off.output, gate.output, "gating must be bit-exact");
    assert_eq!(off.output, enc.output, "A-DBB encoding must be bit-exact");
    assert_eq!(off.output, auto.output);
    assert_eq!(off.act_sparsity, enc.act_sparsity);

    // fixed policies apply everywhere and report as such
    assert!(off.act_policy.iter().all(|&p| p == ActPolicy::Off));
    assert!(off.gate_engaged.iter().all(|&g| !g));
    assert!(gate.act_policy.iter().all(|&p| p == ActPolicy::Gate));
    assert!(enc.act_policy.iter().all(|&p| p == ActPolicy::Encode));
    assert!(enc.gate_engaged.iter().all(|&g| g));

    // Auto resolves per layer from the recorded profile, through the
    // documented thresholds — the same values the hardware twin prices
    for (li, (&s, &p)) in measured.iter().zip(&auto.act_policy).enumerate() {
        assert_eq!(p, ActPolicy::Auto.resolved(s), "layer {li}: s={s}");
    }
    // the near-dense seed input (2% zeros) must keep layer 0 on Off
    assert_eq!(auto.act_policy[0], ActPolicy::Off);

    // and the twin-facing profiles carry exactly the executor's decision
    let profiles = pm.profiles().unwrap();
    for (p, &pol) in profiles.iter().zip(&auto.act_policy) {
        assert_eq!(p.act_encoded, pol == ActPolicy::Encode, "{}", p.name);
    }
}

#[test]
fn encoded_execute_is_pure() {
    // repeated Encode executes are bit-identical: the chunk encoders hold
    // no state across calls (scratch rewritten before every read)
    let m = models::lenet5();
    let pm = PreparedModel::prepare(&m, 2, 8, 9, Parallelism::threads(4));
    let par = Parallelism::threads(4);
    let first = pm.execute_policy(pm.seed_input(), par, ActPolicy::Encode);
    let mut rng = Rng::new(11);
    let other = TensorI8::rand_sparse(&[28, 28, 1], 0.7, &mut rng);
    let _ = pm.execute_policy(&other, par, ActPolicy::Encode);
    let again = pm.execute_policy(pm.seed_input(), par, ActPolicy::Encode);
    assert_eq!(first.output, again.output);
    assert_eq!(first.act_sparsity, again.act_sparsity);
    assert_eq!(first.act_policy, again.act_policy);
}

#[test]
fn profile_is_policy_invariant() {
    // the recorded sparsities cannot depend on the model's default policy —
    // the twin's priced profile is the same whatever the executor does
    let m = models::convnet5();
    let mut base = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
    base.set_act_policy(ActPolicy::Off);
    let p_off = base.profile(Parallelism::serial());
    let mut enc = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
    enc.set_act_policy(ActPolicy::Encode);
    let p_enc = enc.profile(Parallelism::serial());
    for (a, b) in p_off.iter().zip(&p_enc) {
        assert_eq!(a.act_sparsity.to_bits(), b.act_sparsity.to_bits(), "{}", a.name);
    }
    // act_encoded, by contrast, reflects each model's own policy
    assert!(p_off.iter().all(|p| !p.act_encoded));
    assert!(p_enc.iter().all(|p| p.act_encoded));
}
