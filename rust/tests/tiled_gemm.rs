//! Integration: the tiled parallel GEMM engine vs the serial oracles,
//! exercised through the public API exactly as the profiler and coordinator
//! consume it — bit-exactness across shapes, block parameters and thread
//! counts, plus the parallel sweep/profiling wrappers.

use ssta::arch::{space, Tech};
use ssta::dbb::{prune::prune_i8, DbbMatrix};
use ssta::gemm;
use ssta::models;
use ssta::sim::accel::{network_timing, network_timing_with, profile_model_with};
use ssta::tensor::TensorI8;
use ssta::util::prop::{check, Config};
use ssta::util::{Parallelism, Rng};

#[test]
fn tiled_dense_bit_exact_across_thread_counts() {
    check(Config::default().cases(64), |rng| {
        let m = rng.below(96) + 1;
        let k = rng.below(96) + 1;
        let n = rng.below(48) + 1;
        let threads = rng.below(8) + 1;
        let a = TensorI8::rand_sparse(&[m, k], 0.35, rng);
        let w = TensorI8::rand(&[k, n], rng);
        assert_eq!(
            gemm::tiled::dense_i8(&a, &w, Parallelism::threads(threads)).data(),
            gemm::dense_i8(&a, &w).data(),
            "m={m} k={k} n={n} threads={threads}"
        );
    });
}

#[test]
fn tiled_dbb_bit_exact_across_thread_counts() {
    check(Config::default().cases(64), |rng| {
        let m = rng.below(64) + 1;
        let k = rng.below(96) + 1;
        let n = rng.below(32) + 1;
        let bz = [4usize, 8, 16][rng.below(3)];
        let nnz = rng.below(bz) + 1;
        let threads = rng.below(8) + 1;
        let a = TensorI8::rand_sparse(&[m, k], 0.5, rng);
        let wd = prune_i8(&TensorI8::rand(&[k, n], rng), bz, nnz);
        let w = DbbMatrix::compress(&wd, bz).unwrap();
        assert_eq!(
            gemm::tiled::dbb_i8(&a, &w, Parallelism::threads(threads)).data(),
            gemm::dbb_i8(&a, &w).data(),
            "m={m} k={k} n={n} bz={bz} nnz={nnz} threads={threads}"
        );
    });
}

#[test]
fn m_smaller_than_thread_count() {
    // every M in 1..8 against an 8-thread pool — the partition degenerates
    // to one row per worker with idle workers left over
    let mut rng = Rng::new(11);
    for m in 1..8usize {
        let a = TensorI8::rand(&[m, 40], &mut rng);
        let w = TensorI8::rand(&[40, 12], &mut rng);
        assert_eq!(
            gemm::tiled::dense_i8(&a, &w, Parallelism::threads(8)).data(),
            gemm::dense_i8(&a, &w).data(),
            "m={m}"
        );
        let wd = prune_i8(&TensorI8::rand(&[40, 12], &mut rng), 8, 3);
        let wc = DbbMatrix::compress(&wd, 8).unwrap();
        assert_eq!(
            gemm::tiled::dbb_i8(&a, &wc, Parallelism::threads(8)).data(),
            gemm::dbb_i8(&a, &wc).data(),
            "m={m} (dbb)"
        );
    }
}

#[test]
fn large_gemm_spot_check_auto_parallelism() {
    // the bench shape (scaled down) through the default auto pool
    let mut rng = Rng::new(21);
    let a = TensorI8::rand_sparse(&[192, 256], 0.5, &mut rng);
    let wd = prune_i8(&TensorI8::rand(&[256, 96], &mut rng), 8, 3);
    let w = DbbMatrix::compress_with_bound(&wd, 8, 3).unwrap();
    assert_eq!(
        gemm::tiled::dense_i8(&a, &wd, Parallelism::auto()).data(),
        gemm::dense_i8(&a, &wd).data()
    );
    assert_eq!(
        gemm::tiled::dbb_i8(&a, &w, Parallelism::auto()).data(),
        gemm::dbb_i8(&a, &w).data()
    );
}

#[test]
fn parallel_profile_and_sweep_reproduce_serial_results() {
    // the wired-through consumers: layer profiling and the design sweep
    let m = models::convnet5();
    let serial = profile_model_with(&m, 4, 8, 7, Parallelism::serial());
    let parallel = profile_model_with(&m, 4, 8, 7, Parallelism::threads(6));
    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.act_sparsity.to_bits(), b.act_sparsity.to_bits(), "{}", a.name);
        assert_eq!(a.m, b.m);
    }

    let designs = space::enumerate(space::MACS_4TOPS, Tech::N16);
    let cycles_serial = space::sweep(&designs, Parallelism::serial(), |d| {
        network_timing(d, &serial).total.cycles
    });
    let cycles_par = space::sweep(&designs, Parallelism::auto(), |d| {
        network_timing(d, &serial).total.cycles
    });
    assert_eq!(cycles_serial, cycles_par);

    let d = ssta::arch::Design::paper_optimal();
    let t1 = network_timing(&d, &serial);
    let t8 = network_timing_with(&d, &serial, Parallelism::threads(8));
    assert_eq!(t1.total, t8.total);
    assert_eq!(t1.dense_macs, t8.dense_macs);
}
