//! Property suite for the fused output epilogues (`ssta::gemm::epilogue`):
//! every `*_ep` driver — the `tiled::*_ep` GEMM pools, the
//! `fused::conv2d_*_ep` conv stack, and the engine's
//! `PreparedModel::execute_fused` layer chain — must be **bit-exact** with
//! the staged oracle (materialize i32 → `requant_rows` → `max_pool_2x2`)
//! on every ISA the host supports, across activation policies
//! (Off / Gate / Encode / Auto), dense and DBB operands, remainder and
//! degenerate shapes (M < threads, odd pre-pool H/W, 1×1 conv, sub-2×2
//! pooled grids), per-channel requant scales, and repeated executes
//! through the engine's ping-pong scratch.
//!
//! The ISA override (`micro::force_isa`) is process-global, so tests that
//! flip it serialize on one mutex and restore the default through a drop
//! guard (same discipline as `rust/tests/micro_kernels.rs`).

use std::sync::Mutex;

use ssta::dbb::DbbMatrix;
use ssta::engine::PreparedModel;
use ssta::gemm::conv::{weights_to_gemm, ConvShape};
use ssta::gemm::epilogue::{max_pool_2x2, requant_rows};
use ssta::gemm::micro::{self, Isa};
use ssta::gemm::{
    fused, tiled, ActDbb, ActPolicy, DbbPacked, Epilogue, PoolGeom, Requant, ZeroGate,
};
use ssta::models;
use ssta::tensor::{TensorI32, TensorI8};
use ssta::util::prop::{check, Config};
use ssta::util::{Parallelism, Rng};

static ISA_LOCK: Mutex<()> = Mutex::new(());

/// Holds the process-global ISA lock and restores the default dispatch on
/// drop, so a failing case never leaks a forced ISA into the next test.
struct IsaGuard(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

impl IsaGuard {
    fn acquire() -> IsaGuard {
        IsaGuard(ISA_LOCK.lock().unwrap_or_else(|e| e.into_inner()))
    }
}

impl Drop for IsaGuard {
    fn drop(&mut self) {
        micro::force_isa(None);
    }
}

/// Evaluate `eval` under forced-Scalar (the oracle) and then under every
/// ISA the host supports, asserting each i8 result list is bit-identical.
fn exact_on_every_isa<F: Fn() -> Vec<Vec<i8>>>(tag: &str, eval: F) {
    let _guard = IsaGuard::acquire();
    micro::force_isa(Some(Isa::Scalar));
    let want = eval();
    for isa in micro::available_isas() {
        micro::force_isa(Some(isa));
        let got = eval();
        assert_eq!(got.len(), want.len(), "{tag}: variant count under {isa}");
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g, w, "{tag}: variant #{i} diverges from scalar under {isa}");
        }
    }
}

/// Case-count that stays overridable by `SSTA_PROP_CASES` (the miri job
/// shrinks the grid through it; an explicit `.cases(n)` would mask it).
fn cfg(n: u32) -> Config {
    if std::env::var("SSTA_PROP_CASES").is_ok() {
        Config::default()
    } else {
        Config::default().cases(n)
    }
}

/// The staged oracle: requantize the whole materialized i32 result, then
/// (when the epilogue pools) run the separate `max_pool_2x2` pass — the
/// historical layer chain the fused walk replaces.
fn staged(acc: &TensorI32, ep: &Epilogue) -> Vec<i8> {
    let n = *acc.shape().last().unwrap();
    let m = acc.data().len() / n.max(1);
    let mut q = vec![0i8; m * n];
    requant_rows(acc.data(), n, ep.requant(), ep.relu(), &mut q);
    match ep.pool() {
        None => q,
        Some(pg) => max_pool_2x2(&TensorI8::from_vec(&[m, n], q), pg.oh, pg.ow, n).into_vec(),
    }
}

/// Random requant scale: global or per-channel, shifts 0..=3.
fn rand_requant(rng: &mut Rng, n: usize) -> Requant {
    if rng.below(2) == 0 {
        Requant::Global(rng.below(4) as u32)
    } else {
        Requant::PerChannel((0..n).map(|_| rng.below(4) as u32).collect())
    }
}

// ---------------------------------------------------------------------------
// requant kernels vs an independent in-test reference
// ---------------------------------------------------------------------------

/// Independent re-statement of the requant contract (NOT the crate's code):
/// arithmetic right shift, clamp to `[-127, 127]` — never −128 — with the
/// ReLU folded in as a zero lower clamp bound.
fn ref_requant(acc: &[i32], n: usize, rq: &Requant, relu: bool) -> Vec<i8> {
    let lo = if relu { 0i32 } else { -127 };
    acc.iter()
        .enumerate()
        .map(|(i, &v)| {
            let sh = match rq {
                Requant::Global(s) => *s,
                Requant::PerChannel(ss) => ss[i % n],
            };
            (v >> sh).clamp(lo, 127) as i8
        })
        .collect()
}

#[test]
fn requant_kernels_match_reference_on_every_isa() {
    // Row widths crossing the 4/8/16-lane kernel boundaries, extreme
    // values (i32::MIN/MAX and exact ±127 ≪ shift fenceposts), shifts up
    // to 31, global and per-channel scales, ReLU on and off.
    let mut rng = Rng::new(0xE91_0001);
    for &n in &[1usize, 3, 7, 8, 9, 15, 16, 17, 33] {
        for rows in [1usize, 2, 5] {
            let mut acc: Vec<i32> = (0..rows * n)
                .map(|_| (rng.below(1 << 17) as i32) - (1 << 16))
                .collect();
            acc[0] = i32::MIN;
            if acc.len() > 1 {
                acc[1] = i32::MAX;
            }
            for (i, v) in [127 << 1, -(127 << 1), (127 << 1) + 1, -128].iter().enumerate() {
                if 2 + i < acc.len() {
                    acc[2 + i] = *v;
                }
            }
            for relu in [false, true] {
                for rq in [
                    Requant::Global(0),
                    Requant::Global(1),
                    Requant::Global(5),
                    Requant::Global(31),
                    Requant::PerChannel((0..n).map(|c| (c % 4) as u32).collect()),
                    Requant::PerChannel((0..n).map(|_| rng.below(32) as u32).collect()),
                ] {
                    let want = ref_requant(&acc, n, &rq, relu);
                    exact_on_every_isa(&format!("requant n={n} rows={rows} relu={relu}"), || {
                        let mut out = vec![0i8; acc.len()];
                        requant_rows(&acc, n, &rq, relu, &mut out);
                        assert_eq!(out, want, "vs in-test reference");
                        vec![out]
                    });
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// tiled GEMM drivers vs the staged oracle
// ---------------------------------------------------------------------------

#[test]
fn tiled_gemm_epilogues_match_staged_oracle_prop() {
    check(cfg(24), |rng| {
        let m = rng.below(40) + 1;
        let k = rng.below(120) + 1;
        let n = rng.below(24) + 1;
        let threads = rng.below(8) + 1; // includes M < threads
        let relu = rng.below(2) == 0;
        let ep = Epilogue::new(rand_requant(rng, n), relu);
        let bz = [4usize, 8][rng.below(2)];
        let a = TensorI8::rand_sparse(&[m, k], [0.0f32, 0.5, 1.0][rng.below(3)], rng);
        let w = TensorI8::rand(&[k, n], rng);
        let wp = DbbPacked::pack(&DbbMatrix::compress_topk(&w, bz, bz / 2 + 1).unwrap());
        let enc = ActDbb::encode(&a, bz);
        let par = Parallelism::threads(threads);
        let dense_want = staged(&tiled::dense_i8(&a, &w, par), &ep);
        let dbb_want = staged(&tiled::dbb_i8_packed(&a, &wp, par), &ep);
        exact_on_every_isa(&format!("tiled ep m={m} k={k} n={n} t={threads}"), || {
            let got = vec![
                tiled::dense_i8_ep(&a, &w, par, ZeroGate::Off, &ep).into_vec(),
                tiled::dense_i8_ep(&a, &w, par, ZeroGate::On, &ep).into_vec(),
                tiled::adbb_dense_i8_ep(&enc, &w, par, &ep).into_vec(),
                tiled::dbb_i8_packed_ep(&a, &wp, par, ZeroGate::On, &ep).into_vec(),
                tiled::adbb_i8_packed_ep(&enc, &wp, par, &ep).into_vec(),
            ];
            assert_eq!(got[0], dense_want, "dense fused vs staged");
            assert_eq!(got[2], dense_want, "encoded fused vs staged");
            assert_eq!(got[3], dbb_want, "dbb fused vs staged");
            assert_eq!(got[4], dbb_want, "dbb encoded fused vs staged");
            got
        });
    });
}

#[test]
fn pooled_gemm_epilogues_match_staged_oracle_prop() {
    // Pooled tiles must never straddle a worker boundary: odd and even
    // pre-pool grids (odd drops the trailing row/column), multi-image
    // batches, degenerate sub-2×2 grids (empty pooled output), and worker
    // pools wider than the image count.
    check(cfg(24), |rng| {
        let oh = rng.below(7) + 1;
        let ow = rng.below(7) + 1;
        let b = rng.below(3) + 1;
        let m = b * oh * ow;
        let k = rng.below(48) + 1;
        let n = rng.below(12) + 1;
        let threads = rng.below(8) + 1;
        let ep = Epilogue::new(rand_requant(rng, n), rng.below(2) == 0)
            .with_pool(PoolGeom { oh, ow });
        let a = TensorI8::rand_sparse(&[m, k], 0.4, rng);
        let w = TensorI8::rand(&[k, n], rng);
        let wp = DbbPacked::pack(&DbbMatrix::compress_topk(&w, 8, 3).unwrap());
        let par = Parallelism::threads(threads);
        let dense_want = staged(&tiled::dense_i8(&a, &w, par), &ep);
        let dbb_want = staged(&tiled::dbb_i8_packed(&a, &wp, par), &ep);
        assert_eq!(dense_want.len(), ep.out_rows(m) * n, "oracle length");
        exact_on_every_isa(&format!("pooled ep b={b} oh={oh} ow={ow} t={threads}"), || {
            let got = vec![
                tiled::dense_i8_ep(&a, &w, par, ZeroGate::On, &ep).into_vec(),
                tiled::dbb_i8_packed_ep(&a, &wp, par, ZeroGate::Off, &ep).into_vec(),
            ];
            assert_eq!(got[0], dense_want, "pooled dense fused vs staged");
            assert_eq!(got[1], dbb_want, "pooled dbb fused vs staged");
            got
        });
    });
}

// ---------------------------------------------------------------------------
// fused conv drivers vs the staged oracle
// ---------------------------------------------------------------------------

fn rand_conv_shape(rng: &mut Rng) -> ConvShape {
    let kh = [1usize, 3, 5][rng.below(3)]; // includes 1×1 convs
    let stride = rng.below(2) + 1;
    ConvShape {
        h: kh + rng.below(6) + stride,
        w: kh + rng.below(6) + stride,
        c: rng.below(5) + 1,
        kh,
        kw: kh,
        oc: rng.below(16) + 1,
        stride,
        pad: rng.below(kh.div_ceil(2)),
    }
}

#[test]
fn fused_conv_epilogues_match_staged_oracle_prop() {
    check(cfg(16), |rng| {
        let s = rand_conv_shape(rng);
        let batched = rng.below(2) == 0;
        let b = if batched { rng.below(2) + 2 } else { 1 };
        let shape: Vec<usize> = if batched {
            vec![b, s.h, s.w, s.c]
        } else {
            vec![s.h, s.w, s.c]
        };
        let x = TensorI8::rand_sparse(&shape, [0.0f32, 0.5, 1.0][rng.below(3)], rng);
        let w4 = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], rng);
        let wg = weights_to_gemm(&w4, &s);
        let wp = DbbPacked::pack(&DbbMatrix::compress_topk(&wg, 8, 3).unwrap());
        let par = Parallelism::threads(rng.below(6) + 1);
        // pool whenever the epilogue geometry is representable — including
        // odd oh/ow (dropped trailing row/col) and sub-2×2 grids
        let mut ep = Epilogue::new(rand_requant(rng, s.oc), rng.below(2) == 0);
        let pooled = rng.below(2) == 0;
        if pooled {
            ep = ep.with_pool(PoolGeom { oh: s.oh(), ow: s.ow() });
        }
        let dense_want = staged(&fused::conv2d_i8(&x, &w4, &s, par), &ep);
        let dbb_want = staged(&fused::conv2d_dbb_i8_packed(&x, &wp, &s, par), &ep);
        exact_on_every_isa(&format!("conv ep {s:?} b={b} pooled={pooled}"), || {
            let got = vec![
                fused::conv2d_i8_ep(&x, &w4, &s, par, ZeroGate::On, &ep).into_vec(),
                fused::conv2d_i8_ep(&x, &w4, &s, par, ZeroGate::Off, &ep).into_vec(),
                fused::conv2d_i8_encoded_ep(&x, &w4, &s, par, &ep).into_vec(),
                fused::conv2d_dbb_i8_packed_ep(&x, &wp, &s, par, ZeroGate::On, &ep).into_vec(),
                fused::conv2d_dbb_i8_packed_encoded_ep(&x, &wp, &s, par, &ep).into_vec(),
            ];
            assert_eq!(got[0], dense_want, "dense conv fused vs staged");
            assert_eq!(got[2], dense_want, "encoded conv fused vs staged");
            assert_eq!(got[3], dbb_want, "dbb conv fused vs staged");
            assert_eq!(got[4], dbb_want, "dbb encoded conv fused vs staged");
            got
        });
        // and the pooled output tensor carries the halved spatial grid
        if pooled {
            let out = fused::conv2d_i8_ep(&x, &w4, &s, par, ZeroGate::Off, &ep);
            let (ph, pw) = (s.oh() / 2, s.ow() / 2);
            let want_shape: Vec<usize> = if batched {
                vec![b, ph, pw, s.oc]
            } else {
                vec![ph, pw, s.oc]
            };
            assert_eq!(out.shape(), &want_shape[..], "pooled conv shape {s:?}");
        }
    });
}

// ---------------------------------------------------------------------------
// the engine's fused i8→i8 layer chain
// ---------------------------------------------------------------------------

#[test]
#[cfg_attr(miri, ignore = "whole-network chains are a plain-size stress case")]
fn engine_fused_chain_matches_staged_across_policies_and_pool() {
    let model = models::convnet5();
    let par = Parallelism::threads(3);
    let mut pm = PreparedModel::prepare(&model, 3, 8, 0xE91_0002, par);
    let seed = pm.seed_input().clone();
    let mut rng = Rng::new(0xE91_0003);
    let probe = TensorI8::rand_sparse(seed.shape(), 0.3, &mut rng);
    for pool in [false, true] {
        pm.set_fused_pool(pool);
        pm.calibrate(par); // shifts depend on the pool toggle, not policy
        for policy in [ActPolicy::Off, ActPolicy::Gate, ActPolicy::Encode, ActPolicy::Auto] {
            pm.set_act_policy(policy);
            // on the seed input, the frozen shifts ARE the dynamic ones:
            // plain execute, the staged oracle, and the fused chain agree
            let plain = pm.execute(&seed, par);
            let st = pm.execute_staged(&seed, par);
            let fu = pm.execute_fused(&seed, par);
            assert_eq!(
                st.output.data(),
                fu.output.data(),
                "staged vs fused on seed, policy={policy:?} pool={pool}"
            );
            assert_eq!(
                plain.output.data(),
                fu.output.data(),
                "execute vs fused on seed, policy={policy:?} pool={pool}"
            );
            assert_eq!(st.output.shape(), fu.output.shape());
            // on any other input the frozen-shift paths still agree with
            // each other, at every worker-pool width
            let sp = pm.execute_staged(&probe, par);
            for t in [1usize, 2, 5] {
                let fp = pm.execute_fused(&probe, Parallelism::threads(t));
                assert_eq!(
                    sp.output.data(),
                    fp.output.data(),
                    "staged vs fused on probe, policy={policy:?} pool={pool} t={t}"
                );
            }
            // the fused path reports the same per-layer bookkeeping
            assert_eq!(sp.act_sparsity, pm.execute_fused(&probe, par).act_sparsity);
        }
    }
}

#[test]
#[cfg_attr(miri, ignore = "whole-network chains are a plain-size stress case")]
fn engine_fused_chain_exact_on_every_isa() {
    let model = models::lenet5();
    let par = Parallelism::threads(4);
    let mut pm = PreparedModel::prepare(&model, 3, 8, 0xE91_0004, par);
    pm.set_act_policy(ActPolicy::Encode);
    pm.set_fused_pool(true);
    pm.calibrate(par);
    let seed = pm.seed_input().clone();
    exact_on_every_isa("engine fused chain", || {
        let st = pm.execute_staged(&seed, par);
        let fu = pm.execute_fused(&seed, par);
        assert_eq!(st.output.data(), fu.output.data(), "staged vs fused");
        vec![st.output.into_vec(), fu.output.into_vec()]
    });
}

#[test]
#[cfg_attr(miri, ignore = "whole-network chains are a plain-size stress case")]
fn repeated_fused_executes_are_pure() {
    // The ping-pong scratch pool recycles output backings across layers
    // and calls: repeated and interleaved executes must reproduce their
    // first results bit for bit (a stale or aliased buffer would not).
    let model = models::convnet5();
    let par = Parallelism::threads(4);
    let mut pm = PreparedModel::prepare(&model, 2, 8, 0xE91_0005, par);
    pm.set_fused_pool(true);
    pm.calibrate(par);
    let mut rng = Rng::new(0xE91_0006);
    let shape = pm.seed_input().shape().to_vec();
    let xa = TensorI8::rand_sparse(&shape, 0.2, &mut rng);
    let xb = TensorI8::rand_sparse(&shape, 0.8, &mut rng);
    let first_a = pm.execute_fused(&xa, par);
    let first_b = pm.execute_fused(&xb, par);
    for round in 0..3 {
        let again_b = pm.execute_fused(&xb, par);
        let again_a = pm.execute_fused(&xa, par);
        assert_eq!(first_a.output.data(), again_a.output.data(), "round {round} input A");
        assert_eq!(first_b.output.data(), again_b.output.data(), "round {round} input B");
        assert_eq!(first_a.act_sparsity, again_a.act_sparsity, "round {round} sparsities");
    }
}
