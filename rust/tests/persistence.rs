//! Persistence suite for the prepared-model flat-binary format
//! (`PreparedModel::{to_bytes, from_bytes, save, load}`).
//!
//! The format is the paper's §II-A offline-encode artifact made durable: a
//! restarted server loads the stream and serves immediately, skipping
//! synthesize / top-k prune / DBB encode / calibration. Two properties are
//! pinned here:
//!
//! 1. **Bit-exactness** — a round-tripped model reproduces the saved one
//!    exactly: encoding point, operand bytes, calibrated (global and
//!    per-channel) shifts, measured sparsities, and — the property that
//!    actually matters — identical fused-execute outputs, across layer
//!    kinds (conv / depthwise / FC) and a sweep of DBB encoding points.
//! 2. **Robustness** — truncation or corruption anywhere in the stream
//!    yields a clean `Err`, never a panic and never a silently-wrong model
//!    (the trailing FNV-1a checksum is verified before any parsing).

use ssta::engine::{PreparedModel, PERSIST_MAGIC};
use ssta::gemm::conv::ConvShape;
use ssta::models::{Layer, LayerKind, Model};
use ssta::tensor::TensorI8;
use ssta::util::{Parallelism, Rng};

/// A small mixed-kind model: conv → depthwise → conv → FC exercises every
/// `SampleShape`/`PackedOperand` arm of the format, including the dense
/// fallback (depthwise and non-prunable layers persist as `Dense`).
fn mixed_model() -> Model {
    let c1 = ConvShape { h: 12, w: 12, c: 3, kh: 3, kw: 3, oc: 8, stride: 1, pad: 1 };
    let dw = ConvShape { h: 12, w: 12, c: 8, kh: 3, kw: 3, oc: 8, stride: 1, pad: 1 };
    let c2 = ConvShape { h: 12, w: 12, c: 8, kh: 3, kw: 3, oc: 16, stride: 2, pad: 1 };
    Model {
        name: "persist-mixed",
        dataset: "synthetic",
        layers: vec![
            Layer { name: "conv1".into(), kind: LayerKind::Conv(c1), prunable: false },
            Layer { name: "dw".into(), kind: LayerKind::DepthwiseConv(dw), prunable: false },
            Layer { name: "conv2".into(), kind: LayerKind::Conv(c2), prunable: true },
            Layer { name: "fc".into(), kind: LayerKind::Fc(6 * 6 * 16, 10), prunable: true },
        ],
    }
}

/// Prepare + profile + calibrate the mixed model at one encoding point —
/// the exact lowering a serving coordinator runs once per model.
fn served(nnz: usize, bz: usize) -> PreparedModel {
    let par = Parallelism::serial();
    let mut pm = PreparedModel::prepare(&mixed_model(), nnz, bz, 42, par);
    pm.set_fused_epilogue(true);
    pm.profile(par);
    pm.calibrate(par);
    pm
}

/// Round-trip `pm` through bytes and assert the reload is indistinguishable
/// from the original, down to fused-execute outputs on fresh inputs.
fn assert_roundtrip_bit_exact(pm: &PreparedModel, tag: &str) {
    let par = Parallelism::serial();
    let bytes = pm.to_bytes();
    let rt = PreparedModel::from_bytes(&bytes, par)
        .unwrap_or_else(|e| panic!("{tag}: roundtrip failed: {e}"));
    assert_eq!(rt.model_name(), pm.model_name(), "{tag}: name");
    assert_eq!(rt.encoding(), pm.encoding(), "{tag}: encoding point");
    assert_eq!(rt.operand_bytes(), pm.operand_bytes(), "{tag}: packed operand bytes");
    assert_eq!(rt.calibrated_shifts(), pm.calibrated_shifts(), "{tag}: global shifts");
    assert_eq!(
        rt.calibrated_channel_shifts(),
        pm.calibrated_channel_shifts(),
        "{tag}: per-channel shifts"
    );
    // measured sparsities must survive bit-for-bit (the twin prices them)
    let (a, b) = (rt.measured_act_sparsity(), pm.measured_act_sparsity());
    assert_eq!(a.is_some(), b.is_some(), "{tag}: measured presence");
    if let (Some(a), Some(b)) = (a, b) {
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: measured sparsity bits");
        }
    }
    // the property that matters: identical served numbers on fresh inputs
    let mut rng = Rng::new(9);
    for i in 0..3 {
        let x = TensorI8::rand_sparse(&[12, 12, 3], 0.5, &mut rng);
        let want = pm.execute_fused(&x, par);
        let got = rt.execute_fused(&x, par);
        assert_eq!(want.output, got.output, "{tag}: fused output, input {i}");
    }
    // and the stream is deterministic: re-serializing the reload is stable
    assert_eq!(rt.to_bytes(), bytes, "{tag}: canonical re-serialization");
}

#[test]
fn roundtrip_bit_exact_across_encoding_points() {
    for (nnz, bz) in [(2, 4), (3, 8), (8, 8)] {
        let pm = served(nnz, bz);
        assert_roundtrip_bit_exact(&pm, &format!("nnz{nnz}/bz{bz}"));
    }
}

#[test]
fn roundtrip_without_calibration_still_works() {
    // persistence must not require the optional passes: a bare prepare
    // (no profile, no calibrate) round-trips too
    let par = Parallelism::serial();
    let pm = PreparedModel::prepare(&mixed_model(), 3, 8, 42, par);
    let rt = PreparedModel::from_bytes(&pm.to_bytes(), par).unwrap();
    assert!(rt.calibrated_shifts().is_none());
    assert!(rt.measured_act_sparsity().is_none());
    let out = pm.execute(pm.seed_input(), par);
    let out2 = rt.execute(rt.seed_input(), par);
    assert_eq!(out.output, out2.output);
}

#[test]
fn zoo_model_keeps_static_name_and_skips_reprepare() {
    // a zoo model's name resolves back to the zoo's 'static str, and the
    // load path does none of the lowering work (it must be much cheaper
    // than prepare — measured as wall time on the same thread)
    let par = Parallelism::serial();
    let t0 = std::time::Instant::now();
    let mut pm = PreparedModel::prepare(&ssta::models::convnet5(), 3, 8, 42, par);
    pm.profile(par);
    pm.calibrate(par);
    let t_prepare = t0.elapsed();
    let bytes = pm.to_bytes();
    let t1 = std::time::Instant::now();
    let rt = PreparedModel::from_bytes(&bytes, par).unwrap();
    let t_load = t1.elapsed();
    assert_eq!(rt.model_name(), "ConvNet");
    assert_eq!(rt.execute_fused(pm.seed_input(), par).output,
               pm.execute_fused(pm.seed_input(), par).output);
    // load does no synthesize/encode/calibrate; 2x headroom over a pass
    // that takes tens of ms keeps this assertion robust on slow CI
    assert!(
        t_load < t_prepare,
        "load ({t_load:.2?}) should beat prepare+profile+calibrate ({t_prepare:.2?})"
    );
}

#[test]
fn save_load_file_roundtrip() {
    let dir = std::env::temp_dir().join(format!("ssta-persistence-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mixed_nnz3_bz8.ssta");
    let par = Parallelism::serial();
    let pm = served(3, 8);
    pm.save(&path).unwrap();
    let rt = PreparedModel::load(&path, par).unwrap();
    assert_eq!(rt.to_bytes(), pm.to_bytes(), "file roundtrip must be byte-identical");
    assert!(PreparedModel::load(dir.join("missing.ssta"), par).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncation_anywhere_is_a_clean_error() {
    let bytes = served(2, 4).to_bytes();
    let par = Parallelism::serial();
    // cut at a spread of points: inside the magic, the header, the layer
    // table, the packed entries, and the trailing checksum itself
    let cuts: Vec<usize> = (0..16)
        .map(|i| i * bytes.len() / 16)
        .chain([bytes.len() - 1, bytes.len() - 8, bytes.len() - 9])
        .collect();
    for cut in cuts {
        let r = PreparedModel::from_bytes(&bytes[..cut], par);
        assert!(r.is_err(), "truncation at {cut}/{} must fail cleanly", bytes.len());
    }
}

#[test]
fn corruption_anywhere_is_a_clean_error() {
    let bytes = served(2, 4).to_bytes();
    let par = Parallelism::serial();
    // the checksum is verified before parsing, so *any* flipped bit in the
    // body fails; flips in the checksum itself fail the compare
    for &pos in &[0, 3, PERSIST_MAGIC.len() + 2, bytes.len() / 2, bytes.len() - 4] {
        let mut bad = bytes.clone();
        bad[pos] ^= 0x40;
        assert!(
            PreparedModel::from_bytes(&bad, par).is_err(),
            "bit flip at {pos}/{} must fail cleanly",
            bytes.len()
        );
    }
    // wrong magic/version (a future-format file) is rejected even with a
    // valid checksum over the altered body
    let mut future = bytes.clone();
    future[6] = b'9'; // SSTAPM9
    let body_len = future.len() - 8;
    let cs = ssta::util::bin::fnv1a64(&future[..body_len]);
    future[body_len..].copy_from_slice(&cs.to_le_bytes());
    let e = PreparedModel::from_bytes(&future, par).unwrap_err();
    assert!(e.to_string().contains("magic"), "{e}");
}

#[test]
fn garbage_and_empty_inputs_are_rejected() {
    let par = Parallelism::serial();
    assert!(PreparedModel::from_bytes(&[], par).is_err());
    assert!(PreparedModel::from_bytes(b"not a model", par).is_err());
    let mut rng = Rng::new(1);
    let noise: Vec<u8> = (0..4096).map(|_| rng.below(256) as u8).collect();
    assert!(PreparedModel::from_bytes(&noise, par).is_err());
}
