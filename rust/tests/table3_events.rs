//! Integration: Table III's reuse algebra vs *counted* events.
//!
//! The closed-form reuse expressions (`arch::reuse`) must agree with what
//! the detailed simulator actually counts: MACs issued per operand byte
//! entering the array, accumulator updates per MAC, and gating behaviour.

use ssta::arch::{reuse, ArrayDims, Datapath, Design, Tech};
use ssta::dbb::{prune::prune_i8, DbbMatrix};
use ssta::sim::analytic;
use ssta::sim::detailed::simulate_gemm;
use ssta::tensor::TensorI8;
use ssta::util::Rng;

fn mk(a: usize, b: usize, c: usize, m: usize, n: usize, dp: Datapath) -> Design {
    Design {
        dims: ArrayDims { a, b, c, m, n },
        datapath: dp,
        im2col: false,
        act_cg: true,
        tech: Tech::N16,
    }
}

/// Counted inter-TPE reuse over a steady-state GEMM = issued-MAC slots per
/// operand byte entering the array edges, compared against Table III.
#[test]
fn counted_reuse_matches_formulas() {
    let mut rng = Rng::new(17);
    let cases = vec![
        mk(1, 1, 1, 4, 4, Datapath::Dense),
        mk(2, 8, 2, 2, 2, Datapath::Dense),
        mk(2, 8, 2, 2, 2, Datapath::FixedDbb { b: 4 }),
        mk(2, 8, 4, 2, 2, Datapath::Vdbb),
    ];
    for d in cases {
        // big aligned GEMM so edge effects vanish
        let tile_rows = d.dims.a * d.dims.m;
        let tile_cols = d.dims.c * d.dims.n;
        let mg = tile_rows * 6;
        let k = d.dims.b.max(8) * 12;
        let ng = tile_cols * 4;
        let nnz = match d.datapath {
            Datapath::FixedDbb { b } => b,
            Datapath::Vdbb => 3,
            Datapath::Dense => 8,
        };
        let a = TensorI8::rand(&[mg, k], &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[k, ng], &mut rng), 8, nnz);
        let w = DbbMatrix::compress_with_bound(&wd, 8, nnz).unwrap();

        let det = simulate_gemm(&d, &a, &w, 1.0);
        let ev = &det.timing.events;

        // operand bytes entering the array per cycle: weight edge + act edge
        let stats = analytic::WeightStats::of(&w);
        let w_bytes = d.weight_edge_bytes_per_cycle();
        let act_bytes = d.act_edge_bytes_per_cycle(stats.density());
        let issued_per_cycle = (ev.macs_active + ev.macs_gated) as f64 / ev.cycles as f64;
        let counted_reuse = issued_per_cycle / (w_bytes + act_bytes);
        let formula = reuse::inter_tpe_reuse_at(&d, stats.bound);
        // agreement within 25% (partial tiles, fill/drain, index bytes)
        let rel = (counted_reuse - formula).abs() / formula;
        assert!(
            rel < 0.25,
            "design {}: counted {counted_reuse:.2} vs formula {formula:.2}",
            d.label()
        );
    }
}

/// Accumulator reuse: MAC slots per accumulator update.
#[test]
fn acc_reuse_matches_event_ratio() {
    // acc updates are implicit in the power model as issued/acc_reuse; here
    // we verify the invariant that drives it: dense B-way DPs retire B MAC
    // slots per accumulator write, VDBB one.
    let dense = mk(2, 8, 2, 2, 2, Datapath::Dense);
    let vdbb = mk(2, 8, 4, 2, 2, Datapath::Vdbb);
    assert_eq!(reuse::acc_reuse(&dense), 8);
    assert_eq!(reuse::acc_reuse(&vdbb), 1);
    // and fixed DBB retires b per write
    let fdbb = mk(2, 8, 2, 2, 2, Datapath::FixedDbb { b: 4 });
    assert_eq!(reuse::acc_reuse(&fdbb), 4);
}

/// Activation clock gating only works on single-MAC datapaths (Table III):
/// the detailed engine's gated counts must reflect the structural claim —
/// a VDBB design sees gated slots ≈ act sparsity; a wide-DP dense design
/// still issues them but they count as data-gated (same counter), so here
/// we check the *analytic* act-CG capability flags feed the power model
/// with different unit energies.
#[test]
fn gating_capability_affects_power_not_cycles() {
    use ssta::power;
    let mut rng = Rng::new(23);
    let vdbb = mk(2, 8, 4, 2, 2, Datapath::Vdbb);
    let a = TensorI8::rand_sparse(&[64, 64], 0.6, &mut rng);
    let wd = prune_i8(&TensorI8::rand(&[64, 32], &mut rng), 8, 4);
    let w = DbbMatrix::compress_with_bound(&wd, 8, 4).unwrap();
    let r = simulate_gemm(&vdbb, &a, &w, 1.0);

    let mut no_cg = vdbb;
    no_cg.act_cg = false;
    let p_cg = power::power(&vdbb, &r.timing.events).total_mw();
    let p_no = power::power(&no_cg, &r.timing.events).total_mw();
    assert!(p_cg < p_no, "CG must reduce power: {p_cg} vs {p_no}");
}

/// The detailed and analytic engines agree on IM2COL-magnified SRAM
/// accounting too (the Fig 9/10 energy inputs).
#[test]
fn magnified_sram_agreement() {
    let mut rng = Rng::new(31);
    let d = mk(2, 8, 4, 2, 2, Datapath::Vdbb);
    let a = TensorI8::rand(&[48, 72], &mut rng);
    let wd = prune_i8(&TensorI8::rand(&[72, 24], &mut rng), 8, 3);
    let w = DbbMatrix::compress_with_bound(&wd, 8, 3).unwrap();
    for mag in [1.0, 1.5, 3.0] {
        let det = simulate_gemm(&d, &a, &w, mag).timing.events;
        let ana = analytic::gemm_timing_exact(&d, &a, &w, mag).events;
        assert_eq!(det.act_sram_bytes, ana.act_sram_bytes, "mag={mag}");
        assert_eq!(det.act_edge_bytes, ana.act_edge_bytes);
    }
}
