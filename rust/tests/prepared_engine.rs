//! Property suite for the prepared-model inference engine
//! (`ssta::engine`): prepare-once/execute-many must be bit-exact with the
//! historical per-call path that re-encoded weights on every invocation.
//!
//! The oracle below *is* that historical path, reconstructed from the
//! public per-call APIs: draw synthetic weights layer by layer from the
//! seed, `compress_topk` each prunable layer **inside the layer loop**,
//! run the per-call-decoding `fused`/`tiled` kernels, requantize and
//! propagate. The prepared engine must reproduce its per-layer activation
//! sparsities and outputs to the last bit — across layer kinds
//! (conv / depthwise / FC), every DBB bound in `1..=BZ`, serial and
//! multi-threaded pools, and repeated executes.

use ssta::dbb::DbbMatrix;
use ssta::engine::{PreparedModel, SampleShape};
use ssta::gemm::conv::ConvShape;
use ssta::gemm::fused;
use ssta::gemm::tiled;
use ssta::models::{Layer, LayerKind, Model};
use ssta::sim::accel::requant_relu;
use ssta::tensor::TensorI8;
use ssta::util::{Parallelism, Rng};

/// Mirrors the engine's wrap-around feature-map fitting.
fn fit_fmap(p: &TensorI8, h: usize, w: usize, c: usize) -> TensorI8 {
    let (ph, pw, pc) = (p.shape()[0], p.shape()[1], p.shape()[2]);
    let mut out = TensorI8::zeros(&[h, w, c]);
    for y in 0..h {
        for x in 0..w {
            for ci in 0..c {
                out.set(&[y, x, ci], p.at(&[y % ph, x % pw, ci % pc]));
            }
        }
    }
    out
}

fn fit_matrix(p: &TensorI8, m: usize, k: usize) -> TensorI8 {
    let pd = p.data();
    TensorI8::from_vec(&[m, k], (0..m * k).map(|i| pd[i % pd.len()]).collect())
}

/// The pre-refactor functional profile: per-call `compress_topk` in the
/// layer loop, per-call CSC decode in every GEMM. Returns per-layer input
/// sparsities and the final requantized output. `samples` carries the
/// sampled geometry (read from the prepared model, whose sampling logic is
/// the historical one moved verbatim).
fn oracle_profile(
    model: &Model,
    nnz: usize,
    bz: usize,
    seed: u64,
    par: Parallelism,
    samples: &[SampleShape],
) -> (Vec<f64>, TensorI8) {
    const SAMPLE_COLS: usize = 256;
    const SEED_ACT_SPARSITY: f32 = 0.02;
    let mut rng = Rng::new(seed);
    let nlayers = model.layers.len();
    let mut fmap: Option<TensorI8> = None;
    let mut sparsities = Vec::with_capacity(nlayers);
    for (li, l) in model.layers.iter().enumerate() {
        let (_, k, n) = l.gemm_dims();
        let bound = l.dbb_bound(nnz, bz);
        let relu = li + 1 < nlayers;
        let ns = n.min(SAMPLE_COLS);
        let w_dense = TensorI8::rand(&[k, ns], &mut rng);
        let (acc, in_s) = match samples[li] {
            SampleShape::Conv(ss) => {
                let x = match &fmap {
                    None => TensorI8::rand_sparse(
                        &[ss.h, ss.w, ss.c],
                        SEED_ACT_SPARSITY,
                        &mut rng,
                    ),
                    Some(p) => fit_fmap(p, ss.h, ss.w, ss.c),
                };
                let in_s = x.sparsity();
                let acc = if bound < bz {
                    // the per-call encode the engine hoists into prepare
                    let enc = DbbMatrix::compress_topk(&w_dense, bz, bound).unwrap();
                    fused::conv2d_dbb_i8(&x, &enc, &ss, par)
                } else {
                    fused::conv2d_i8(&x, &w_dense, &ss, par)
                };
                (acc, in_s)
            }
            SampleShape::Fc { m: ms, k } => {
                let a = match &fmap {
                    None => TensorI8::rand_sparse(&[ms, k], SEED_ACT_SPARSITY, &mut rng),
                    Some(p) => fit_matrix(p, ms, k),
                };
                let in_s = a.sparsity();
                let acc = if bound < bz {
                    let enc = DbbMatrix::compress_topk(&w_dense, bz, bound).unwrap();
                    tiled::dbb_i8(&a, &enc, par)
                } else {
                    tiled::dense_i8(&a, &w_dense, par)
                };
                (acc, in_s)
            }
        };
        sparsities.push(in_s);
        let out = requant_relu(&acc, relu);
        fmap = Some(if out.shape().len() == 3 {
            out
        } else {
            let (om, on) = (out.shape()[0], out.shape()[1]);
            out.reshape(&[1, om, on])
        });
    }
    (sparsities, fmap.expect("model has layers"))
}

/// Small model covering every layer kind: standard conv (dense fallback +
/// DBB), strided conv, depthwise conv, and two FC layers.
fn tiny_mixed_model() -> Model {
    let shp = |h, c, oc, stride, pad| ConvShape { h, w: h, c, kh: 3, kw: 3, oc, stride, pad };
    Model {
        name: "tiny-mix",
        dataset: "synthetic",
        layers: vec![
            Layer {
                name: "conv1".into(),
                kind: LayerKind::Conv(shp(12, 3, 8, 1, 1)),
                prunable: false,
            },
            Layer {
                name: "conv2".into(),
                kind: LayerKind::Conv(shp(12, 8, 16, 2, 1)),
                prunable: true,
            },
            Layer {
                name: "dw".into(),
                kind: LayerKind::DepthwiseConv(shp(6, 16, 16, 1, 1)),
                prunable: false,
            },
            Layer { name: "fc1".into(), kind: LayerKind::Fc(576, 32), prunable: true },
            Layer { name: "fc2".into(), kind: LayerKind::Fc(32, 10), prunable: false },
        ],
    }
}

fn assert_prepared_matches_oracle(model: &Model, nnz: usize, bz: usize, seed: u64, threads: usize) {
    let par = Parallelism::threads(threads);
    let mut pm = PreparedModel::prepare(model, nnz, bz, seed, par);
    let samples: Vec<SampleShape> = pm.layers().iter().map(|l| l.sample).collect();
    let profiles = pm.profile(par);
    let (want_sp, want_out) = oracle_profile(model, nnz, bz, seed, par, &samples);
    assert_eq!(profiles.len(), want_sp.len());
    for (p, w) in profiles.iter().zip(&want_sp) {
        assert_eq!(
            p.act_sparsity.to_bits(),
            w.to_bits(),
            "{}: prepared {} vs oracle {} (nnz={nnz} seed={seed} threads={threads})",
            p.name,
            p.act_sparsity,
            w
        );
    }
    let exec = pm.execute(pm.seed_input(), par);
    assert_eq!(exec.output, want_out, "final output (nnz={nnz} seed={seed})");
}

#[test]
fn prepared_matches_oracle_across_layer_kinds() {
    // conv + depthwise + FC, dense fallback and DBB layers in one net
    let m = tiny_mixed_model();
    assert_prepared_matches_oracle(&m, 3, 8, 42, 1);
    assert_prepared_matches_oracle(&m, 3, 8, 42, 4);
}

#[test]
fn prepared_matches_oracle_every_dbb_bound() {
    // nnz = 1..=BZ: every density bound, including the bound == bz dense
    // degenerate
    let m = tiny_mixed_model();
    for nnz in 1..=8usize {
        assert_prepared_matches_oracle(&m, nnz, 8, 7 + nnz as u64, 3);
    }
}

#[test]
fn prepared_matches_oracle_on_served_model() {
    // convnet5 is what the serving coordinator prepares at startup
    let m = ssta::models::convnet5();
    assert_prepared_matches_oracle(&m, 3, 8, 42, 4);
}

#[test]
fn serial_and_parallel_prepared_profiles_identical() {
    let m = tiny_mixed_model();
    let mut serial = PreparedModel::prepare(&m, 2, 8, 11, Parallelism::serial());
    let mut auto = PreparedModel::prepare(&m, 2, 8, 11, Parallelism::auto());
    let ps = serial.profile(Parallelism::serial());
    let pa = auto.profile(Parallelism::auto());
    for (a, b) in ps.iter().zip(&pa) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.act_sparsity.to_bits(), b.act_sparsity.to_bits(), "{}", a.name);
    }
}

#[test]
fn repeated_execute_has_no_state_leakage() {
    // executes reuse the scratch arena; results must never drift
    let m = tiny_mixed_model();
    let pm = PreparedModel::prepare(&m, 3, 8, 5, Parallelism::threads(4));
    let first = pm.execute(pm.seed_input(), Parallelism::threads(4));
    for _ in 0..4 {
        let again = pm.execute(pm.seed_input(), Parallelism::threads(4));
        assert_eq!(again.output, first.output);
        assert_eq!(again.act_sparsity, first.act_sparsity);
    }
    // a different input in between must not perturb subsequent runs
    let mut rng = Rng::new(99);
    let other = TensorI8::rand(&[5, 5, 3], &mut rng);
    let _ = pm.execute(&other, Parallelism::threads(4));
    let after = pm.execute(pm.seed_input(), Parallelism::threads(4));
    assert_eq!(after.output, first.output);
}

#[test]
fn profile_model_wrapper_is_the_prepared_path() {
    // the public sim::accel wrapper and a hand-held PreparedModel agree
    let m = tiny_mixed_model();
    let via_wrapper = ssta::sim::accel::profile_model_with(&m, 3, 8, 42, Parallelism::serial());
    let mut pm = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
    let direct = pm.profile(Parallelism::serial());
    for (a, b) in via_wrapper.iter().zip(&direct) {
        assert_eq!(a.act_sparsity.to_bits(), b.act_sparsity.to_bits(), "{}", a.name);
        assert_eq!(a.m, b.m);
        assert_eq!(a.weights.bound, b.weights.bound);
    }
}
