//! Integration: the fused streaming-IM2COL convolution engine vs the
//! direct-convolution and materialized-IM2COL oracles, exercised through the
//! public API exactly as the profiler and the train layer consume it —
//! bit-exactness across kernel sizes (1×1 through 7×7), strides, padding,
//! DBB bounds 1..=BZ and thread counts (including M < threads), plus the
//! cross-checks tying the engine to the hardware IM2COL-unit model.

use ssta::dbb::{prune::prune_i8, DbbMatrix};
use ssta::gemm;
use ssta::gemm::conv::{conv2d_direct, im2col, im2col_expansion, weights_to_gemm, ConvShape};
use ssta::gemm::fused::{self, patch_row_into};
use ssta::sim::im2col::Im2colUnit;
use ssta::tensor::TensorI8;
use ssta::util::prop::{check, Config};
use ssta::util::{Parallelism, Rng};

fn rand_shape(rng: &mut Rng) -> ConvShape {
    let kh = [1usize, 3, 5, 7][rng.below(4)];
    let stride = rng.below(2) + 1;
    let pad = rng.below(kh.div_ceil(2));
    ConvShape {
        h: kh + rng.below(8) + stride,
        w: kh + rng.below(8) + stride,
        c: rng.below(8) + 1,
        kh,
        kw: kh,
        oc: rng.below(8) + 1,
        stride,
        pad,
    }
}

#[test]
fn fused_dense_bit_exact_with_direct_across_threads() {
    check(Config::default().cases(64), |rng| {
        let s = rand_shape(rng);
        let threads = rng.below(8) + 1;
        let b = rng.below(3) + 1;
        let x = TensorI8::rand_sparse(&[b, s.h, s.w, s.c], 0.3, rng);
        let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], rng);
        let got = fused::conv2d_i8(&x, &w, &s, Parallelism::threads(threads));
        assert_eq!(got.shape(), &[b, s.oh(), s.ow(), s.oc]);
        let img = s.h * s.w * s.c;
        let out = s.oh() * s.ow() * s.oc;
        for bi in 0..b {
            let xi = TensorI8::from_vec(
                &[s.h, s.w, s.c],
                x.data()[bi * img..(bi + 1) * img].to_vec(),
            );
            let want = conv2d_direct(&xi, &w, &s);
            assert_eq!(
                &got.data()[bi * out..(bi + 1) * out],
                want.data(),
                "shape={s:?} threads={threads} image={bi}"
            );
        }
    });
}

#[test]
fn fused_dbb_bit_exact_across_bounds_and_threads() {
    // DBB bounds 1..=BZ (incl. fully dense blocks), random thread counts
    check(Config::default().cases(48), |rng| {
        let s = rand_shape(rng);
        let bz = [4usize, 8, 16][rng.below(3)];
        let nnz = rng.below(bz) + 1;
        let threads = rng.below(8) + 1;
        let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], 0.4, rng);
        let wd = prune_i8(&TensorI8::rand(&[s.gemm_k(), s.oc], rng), bz, nnz);
        let enc = DbbMatrix::compress(&wd, bz).unwrap();
        let a = im2col(&x, &s);
        let want = gemm::dbb_i8(&a, &enc);
        let got = fused::conv2d_dbb_i8(&x, &enc, &s, Parallelism::threads(threads));
        assert_eq!(
            got.data(),
            want.data(),
            "shape={s:?} bz={bz} nnz={nnz} threads={threads}"
        );
        // and through the dense decompressed oracle
        let wh = wd.reshape(&[s.kh, s.kw, s.c, s.oc]);
        assert_eq!(got.data(), conv2d_direct(&x, &wh, &s).data());
    });
}

#[test]
fn every_dbb_bound_one_through_bz() {
    let mut rng = Rng::new(17);
    let s = ConvShape { h: 8, w: 8, c: 8, kh: 3, kw: 3, oc: 6, stride: 1, pad: 1 };
    let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], 0.5, &mut rng);
    for nnz in 1..=8usize {
        let wd = prune_i8(&TensorI8::rand(&[s.gemm_k(), s.oc], &mut rng), 8, nnz);
        let enc = DbbMatrix::compress(&wd, 8).unwrap();
        let want = gemm::dbb_i8(&im2col(&x, &s), &enc);
        let got = fused::conv2d_dbb_i8(&x, &enc, &s, Parallelism::threads(4));
        assert_eq!(got.data(), want.data(), "nnz={nnz}");
    }
}

#[test]
fn pointwise_degenerates_to_plain_gemm() {
    // 1×1 stride-1: the fused conv must equal the tiled GEMM on the
    // feature map reshaped to [h·w, c] — no patch expansion at all
    let mut rng = Rng::new(23);
    let s = ConvShape { h: 7, w: 9, c: 16, kh: 1, kw: 1, oc: 12, stride: 1, pad: 0 };
    let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], 0.4, &mut rng);
    let w = TensorI8::rand(&[s.c, s.oc], &mut rng);
    let a = x.reshape(&[s.h * s.w, s.c]);
    let want = gemm::tiled::dense_i8(&a, &w, Parallelism::threads(4));
    let got = fused::conv2d_i8(&x, &w, &s, Parallelism::threads(4));
    assert_eq!(got.data(), want.data());
    assert!((im2col_expansion(&s) - 1.0).abs() < 1e-12);
}

#[test]
fn kernel_taller_than_row_buffer_still_exact() {
    // 7×7 > the unit's 6 buffered rows: the hardware model gives up on
    // reuse (magnification 1.0) but the fused software engine is exact for
    // any kernel size
    let mut rng = Rng::new(29);
    let s = ConvShape { h: 14, w: 14, c: 3, kh: 7, kw: 7, oc: 8, stride: 2, pad: 3 };
    let u = Im2colUnit::default();
    assert!(s.kh > u.buf_rows);
    assert_eq!(u.magnification(&s), 1.0);
    let x = TensorI8::rand(&[s.h, s.w, s.c], &mut rng);
    let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], &mut rng);
    assert_eq!(
        fused::conv2d_i8(&x, &w, &s, Parallelism::threads(6)).data(),
        conv2d_direct(&x, &w, &s).data()
    );
}

#[test]
fn m_smaller_than_thread_count() {
    // a single output pixel against an 8-thread pool
    let mut rng = Rng::new(31);
    let s = ConvShape { h: 3, w: 3, c: 4, kh: 3, kw: 3, oc: 5, stride: 1, pad: 0 };
    assert_eq!(s.gemm_m(), 1);
    let x = TensorI8::rand(&[s.h, s.w, s.c], &mut rng);
    let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], &mut rng);
    assert_eq!(
        fused::conv2d_i8(&x, &w, &s, Parallelism::threads(8)).data(),
        conv2d_direct(&x, &w, &s).data()
    );
    let wd = prune_i8(&TensorI8::rand(&[s.gemm_k(), s.oc], &mut rng), 8, 3);
    let enc = DbbMatrix::compress(&wd, 8).unwrap();
    assert_eq!(
        fused::conv2d_dbb_i8(&x, &enc, &s, Parallelism::threads(8)).data(),
        gemm::dbb_i8(&im2col(&x, &s), &enc).data()
    );
}

#[test]
fn shared_row_generator_matches_unit_and_software_im2col() {
    // one generator, three views: fused patch rows == hardware-unit
    // functional path == materialized im2col rows
    check(Config::default().cases(48), |rng| {
        let s = rand_shape(rng);
        let x = TensorI8::rand(&[s.h, s.w, s.c], rng);
        let sw = im2col(&x, &s);
        let u = Im2colUnit::default();
        let (oy, ox) = (rng.below(s.oh()), rng.below(s.ow()));
        let unit_row = u.generate_row(&x, &s, oy, ox);
        let mut fused_row = vec![0i8; s.gemm_k()];
        patch_row_into(x.data(), &s, oy, ox, &mut fused_row);
        let want: Vec<i8> =
            (0..s.gemm_k()).map(|k| sw.at(&[oy * s.ow() + ox, k])).collect();
        assert_eq!(fused_row, want, "shape={s:?} oy={oy} ox={ox}");
        assert_eq!(unit_row, want, "shape={s:?} oy={oy} ox={ox}");
    });
}

#[test]
fn expansion_upper_bounds_unit_magnification() {
    // the two expansion formulas, cross-tested: the total operand blowup of
    // the materializing lowering (im2col_expansion) bounds what the row
    // buffer can regenerate (magnification). They differ because expansion
    // counts *all* duplication (horizontal + vertical + padding, edge
    // effects included) while the unit only banks the vertical reuse its
    // buf_rows geometry captures; subsampling convs (stride > kh) contract
    // the operand (expansion < 1) and bypass the unit (magnification 1) —
    // hence the clamp at 1.
    let u = Im2colUnit::default();
    check(Config::default().cases(256), |rng| {
        let s = rand_shape(rng);
        let e = im2col_expansion(&s);
        let m = u.magnification(&s);
        assert!(m >= 1.0, "magnification is a reduction factor: {m} for {s:?}");
        assert!(
            e.max(1.0) + 1e-12 >= m,
            "expansion {e} < magnification {m} for {s:?}"
        );
    });
}

#[test]
fn gemm_and_hwco_weight_layouts_agree() {
    let mut rng = Rng::new(41);
    let s = ConvShape { h: 10, w: 8, c: 5, kh: 3, kw: 3, oc: 7, stride: 1, pad: 1 };
    let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], &mut rng);
    let x = TensorI8::rand(&[s.h, s.w, s.c], &mut rng);
    let wg = weights_to_gemm(&w, &s);
    assert_eq!(
        fused::conv2d_i8(&x, &w, &s, Parallelism::auto()).data(),
        fused::conv2d_i8(&x, &wg, &s, Parallelism::auto()).data()
    );
}
