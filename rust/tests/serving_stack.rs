//! Integration: the serving stack over real artifacts (skipped when
//! `make artifacts` hasn't run) — runtime ↔ coordinator ↔ hardware twin,
//! plus cross-validation of the XLA functional path against the rust
//! golden GEMM for every compiled density bound.

use std::path::PathBuf;

use ssta::coordinator::{Config, Coordinator};
use ssta::dbb::{prune::prune_i8, DbbMatrix};
use ssta::runtime::{HostTensor, Runtime};
use ssta::tensor::TensorI8;
use ssta::util::Rng;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// Pack a DbbMatrix into the kernel's [KB, NNZ, N] (vals, idx) layout.
fn pack(w: &DbbMatrix, nnz: usize) -> (Vec<i8>, Vec<i32>) {
    let (kb, n) = (w.kblocks(), w.n);
    let mut vals = vec![0i8; kb * nnz * n];
    let mut idx = vec![0i32; kb * nnz * n];
    for col in 0..n {
        for kbi in 0..kb {
            let blk = w.block(col, kbi);
            for (s, (v, p)) in blk.vals.iter().zip(blk.positions()).enumerate() {
                vals[(kbi * nnz + s) * n + col] = *v;
                idx[(kbi * nnz + s) * n + col] = p as i32;
            }
        }
    }
    (vals, idx)
}

#[test]
fn every_gemm_artifact_matches_rust_golden() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rt = Runtime::open(&dir).unwrap();
    let names: Vec<String> = rt
        .artifact_names()
        .iter()
        .filter(|n| n.starts_with("dbb_gemm"))
        .map(|s| s.to_string())
        .collect();
    assert!(!names.is_empty());
    let mut rng = Rng::new(55);
    for name in names {
        let meta = rt.meta(&name).unwrap().clone();
        let (m, k) = (meta.inputs[0].shape[0], meta.inputs[0].shape[1]);
        let (kb, nnz, n) = (
            meta.inputs[1].shape[0],
            meta.inputs[1].shape[1],
            meta.inputs[1].shape[2],
        );
        assert_eq!(kb * 8, k, "{name}: block coverage");
        let a = TensorI8::rand_sparse(&[m, k], 0.4, &mut rng);
        let wd = prune_i8(&TensorI8::rand(&[k, n], &mut rng), 8, nnz);
        let w = DbbMatrix::compress_with_bound(&wd, 8, nnz).unwrap();
        let (vals, idx) = pack(&w, nnz);
        let outs = rt
            .execute(
                &name,
                &[HostTensor::I8(a.data().to_vec()), HostTensor::I8(vals), HostTensor::I32(idx)],
            )
            .unwrap();
        let golden = ssta::gemm::dense_i8(&a, &wd);
        assert_eq!(outs[0].as_i32(), golden.data(), "{name} vs golden");
    }
}

#[test]
fn coordinator_under_concurrent_load() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let coord = Coordinator::start(Config {
        artifacts_dir: dir,
        use_xla: true, // this suite exists to exercise the artifact path
        ..Config::default()
    })
    .unwrap();
    let n_threads = 4;
    let per_thread = 8;
    let mut joins = Vec::new();
    for t in 0..n_threads {
        let h = coord.handle();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + t as u64);
            let mut ok = 0;
            for i in 0..per_thread {
                let img: Vec<f32> = (0..32 * 32 * 3).map(|_| rng.f32()).collect();
                let id = (t * per_thread + i) as u64;
                let resp = h.infer(id, img).unwrap();
                assert_eq!(resp.id, id);
                assert_eq!(resp.logits.len(), 10);
                ok += 1;
            }
            ok
        }));
    }
    let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
    assert_eq!(total, n_threads * per_thread);
    let m = coord.metrics();
    assert_eq!(m.requests as usize, total);
    assert!(m.sim_cycles > 0 && m.sim_energy_mj > 0.0);
    coord.shutdown().unwrap();
}

#[test]
fn coordinator_survives_dropped_callers() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let coord = Coordinator::start(Config {
        artifacts_dir: dir,
        use_xla: true,
        ..Config::default()
    })
    .unwrap();
    let h = coord.handle();
    let mut rng = Rng::new(2);
    // submit and immediately drop the receivers — the coordinator must not
    // wedge or error out
    for i in 0..5 {
        let img: Vec<f32> = (0..32 * 32 * 3).map(|_| rng.f32()).collect();
        drop(h.submit(i, img).unwrap());
    }
    // a live request afterwards still works
    let img: Vec<f32> = (0..32 * 32 * 3).map(|_| rng.f32()).collect();
    let resp = h.infer(99, img).unwrap();
    assert_eq!(resp.id, 99);
    coord.shutdown().unwrap();
}

#[test]
fn manifest_layer_stats_power_the_twin() {
    // the artifact manifest's per-layer weight stats must agree with the
    // rust model zoo's ConvNet-5 (the twin is built from the zoo)
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::open(&dir).unwrap();
    let meta = rt.meta("convnet5_b1").unwrap();
    let layers = meta.raw.get("layers").and_then(|j| j.as_obj()).expect("layer stats");
    let zoo = ssta::models::convnet5();
    for l in zoo.layers.iter() {
        let name = &l.name;
        let entry = layers.get(name).unwrap_or_else(|| panic!("manifest missing {name}"));
        let (_, k, n) = l.gemm_dims();
        assert_eq!(entry.get("k").unwrap().as_usize(), Some(k), "{name} k");
        assert_eq!(entry.get("n").unwrap().as_usize(), Some(n), "{name} n");
    }
}
