//! Property suite for activation-side zero-gating (`ssta::gemm::ZeroGate`):
//! the gated kernels must be **bit-exact** with their ungated counterparts
//! under every policy, for every operand sparsity (0.0 / 0.5 / 1.0,
//! including all-zero rows), every layer kind (dense GEMM, DBB GEMM,
//! fused conv), and every worker-pool width (including `M < threads`);
//! `Auto` must follow its documented threshold; and
//! `PreparedModel::execute` must stay pure with gating forced on.

use ssta::dbb::DbbMatrix;
use ssta::engine::PreparedModel;
use ssta::gemm;
use ssta::gemm::conv::ConvShape;
use ssta::gemm::{fused, tiled, DbbPacked, ZeroGate};
use ssta::models;
use ssta::tensor::TensorI8;
use ssta::util::prop::{check, Config};
use ssta::util::{Parallelism, Rng};

const GATES: [ZeroGate; 3] = [ZeroGate::Off, ZeroGate::Auto, ZeroGate::On];
const SPARSITIES: [f32; 3] = [0.0, 0.5, 1.0];

#[test]
fn dense_gated_bit_exact_across_sparsity_and_threads() {
    check(Config::default().cases(96), |rng| {
        let m = rng.below(40) + 1;
        let k = rng.below(64) + 1;
        let n = rng.below(24) + 1;
        let threads = rng.below(8) + 1; // includes M < threads
        let p_zero = SPARSITIES[rng.below(3)];
        let gate = GATES[rng.below(3)];
        let a = TensorI8::rand_sparse(&[m, k], p_zero, rng);
        let w = TensorI8::rand(&[k, n], rng);
        let want = gemm::dense_i8(&a, &w);
        assert_eq!(
            gemm::dense_i8_gated(&a, &w, gate).data(),
            want.data(),
            "serial m={m} k={k} n={n} p={p_zero} gate={gate:?}"
        );
        assert_eq!(
            tiled::dense_i8_gated(&a, &w, Parallelism::threads(threads), gate).data(),
            want.data(),
            "tiled m={m} k={k} n={n} threads={threads} p={p_zero} gate={gate:?}"
        );
    });
}

#[test]
fn dbb_gated_bit_exact_across_sparsity_and_threads() {
    check(Config::default().cases(96), |rng| {
        let m = rng.below(32) + 1;
        let k = rng.below(64) + 1;
        let n = rng.below(20) + 1;
        let bz = [4usize, 8, 16][rng.below(3)];
        let nnz = rng.below(bz) + 1; // DBB bounds 1..=BZ
        let threads = rng.below(8) + 1;
        let p_zero = SPARSITIES[rng.below(3)];
        let gate = GATES[rng.below(3)];
        let a = TensorI8::rand_sparse(&[m, k], p_zero, rng);
        let w = DbbMatrix::compress_topk(&TensorI8::rand(&[k, n], rng), bz, nnz).unwrap();
        let packed = DbbPacked::pack(&w);
        let want = gemm::dbb_i8(&a, &w);
        assert_eq!(
            gemm::dbb_i8_packed_gated(&a, &packed, gate).data(),
            want.data(),
            "serial m={m} k={k} n={n} bz={bz} nnz={nnz} p={p_zero} gate={gate:?}"
        );
        assert_eq!(
            tiled::dbb_i8_packed_gated(&a, &packed, Parallelism::threads(threads), gate).data(),
            want.data(),
            "tiled m={m} k={k} n={n} bz={bz} nnz={nnz} threads={threads} p={p_zero} \
             gate={gate:?}"
        );
    });
}

#[test]
fn fused_conv_gated_bit_exact_across_sparsity_and_threads() {
    check(Config::default().cases(64), |rng| {
        let kh = [1usize, 3, 5][rng.below(3)];
        let stride = rng.below(2) + 1;
        let s = ConvShape {
            h: kh + rng.below(6) + stride,
            w: kh + rng.below(6) + stride,
            c: rng.below(8) + 1,
            kh,
            kw: kh,
            oc: rng.below(8) + 1,
            stride,
            pad: rng.below(kh.div_ceil(2)),
        };
        let threads = rng.below(8) + 1;
        let p_zero = SPARSITIES[rng.below(3)];
        let gate = GATES[rng.below(3)];
        let par = Parallelism::threads(threads);
        let x = TensorI8::rand_sparse(&[s.h, s.w, s.c], p_zero, rng);
        let w = TensorI8::rand(&[s.kh, s.kw, s.c, s.oc], rng);
        assert_eq!(
            fused::conv2d_i8_gated(&x, &w, &s, par, gate).data(),
            fused::conv2d_i8(&x, &w, &s, par).data(),
            "dense conv shape={s:?} threads={threads} p={p_zero} gate={gate:?}"
        );
        let enc = DbbMatrix::compress_topk(
            &TensorI8::rand(&[s.gemm_k(), s.oc], rng),
            8,
            rng.below(8) + 1,
        )
        .unwrap();
        let packed = DbbPacked::pack(&enc);
        assert_eq!(
            fused::conv2d_dbb_i8_packed_gated(&x, &packed, &s, par, gate).data(),
            fused::conv2d_dbb_i8_packed(&x, &packed, &s, par).data(),
            "dbb conv shape={s:?} threads={threads} p={p_zero} gate={gate:?}"
        );
    });
}

#[test]
fn all_zero_operand_gives_zero_output_under_every_gate() {
    // the degenerate case the gate optimizes hardest: every row skipped
    let a = TensorI8::zeros(&[5, 24]);
    let mut rng = Rng::new(3);
    let wd = TensorI8::rand(&[24, 7], &mut rng);
    let enc = DbbMatrix::compress_topk(&wd, 8, 3).unwrap();
    let packed = DbbPacked::pack(&enc);
    for gate in GATES {
        assert!(
            gemm::dense_i8_gated(&a, &wd, gate).data().iter().all(|&v| v == 0),
            "dense gate={gate:?}"
        );
        assert!(
            tiled::dbb_i8_packed_gated(&a, &packed, Parallelism::threads(8), gate)
                .data()
                .iter()
                .all(|&v| v == 0),
            "dbb gate={gate:?}"
        );
    }
}

#[test]
fn auto_threshold_boundary() {
    // the documented contract: Auto engages exactly at AUTO_THRESHOLD
    assert!(!ZeroGate::Auto.engaged(0.0));
    assert!(!ZeroGate::Auto.engaged(ZeroGate::AUTO_THRESHOLD - f64::EPSILON));
    assert!(ZeroGate::Auto.engaged(ZeroGate::AUTO_THRESHOLD));
    assert!(ZeroGate::Auto.engaged(1.0));
    // Off/On ignore the measurement entirely
    for s in [0.0, 0.5, 1.0] {
        assert!(!ZeroGate::Off.engaged(s));
        assert!(ZeroGate::On.engaged(s));
    }
}

#[test]
fn auto_resolves_per_layer_in_the_engine() {
    // a dense input must leave Auto off; an all-zero input must engage it
    // (unprofiled model: Auto falls back to the measured input operand)
    let m = models::lenet5();
    let pm = PreparedModel::prepare(&m, 2, 8, 5, Parallelism::serial());
    let mut rng = Rng::new(8);
    let dense_in = TensorI8::rand(&[28, 28, 1], &mut rng);
    let run = pm.execute_gated(&dense_in, Parallelism::serial(), ZeroGate::Auto);
    assert!(
        !run.gate_engaged[0],
        "dense input (sparsity {}) must not gate layer 0",
        run.act_sparsity[0]
    );
    let zero_in = TensorI8::zeros(&[28, 28, 1]);
    let run = pm.execute_gated(&zero_in, Parallelism::serial(), ZeroGate::Auto);
    assert!(run.gate_engaged[0], "all-zero input must gate layer 0");
    // per-layer decisions always mirror the threshold on the consulted
    // sparsity (here: the measured input operand of each layer)
    for (li, (&s, &g)) in run.act_sparsity.iter().zip(&run.gate_engaged).enumerate() {
        assert_eq!(g, ZeroGate::Auto.engaged(s), "layer {li}: s={s}");
    }
}

#[test]
fn execute_purity_with_gating_on() {
    // repeated gated executes must be bit-identical — the gate introduces
    // no mutable state (scratch buffers are rewritten before every read)
    let m = models::convnet5();
    let pm = PreparedModel::prepare(&m, 3, 8, 7, Parallelism::threads(4));
    let par = Parallelism::threads(4);
    let first = pm.execute_gated(pm.seed_input(), par, ZeroGate::On);
    for _ in 0..3 {
        let again = pm.execute_gated(pm.seed_input(), par, ZeroGate::On);
        assert_eq!(first.output, again.output);
        assert_eq!(first.act_sparsity, again.act_sparsity);
        assert_eq!(first.gate_engaged, again.gate_engaged);
    }
    // interleave a different input, then re-check: no cross-contamination
    let mut rng = Rng::new(9);
    let other = TensorI8::rand_sparse(&[32, 32, 3], 0.6, &mut rng);
    let _ = pm.execute_gated(&other, par, ZeroGate::On);
    let after = pm.execute_gated(pm.seed_input(), par, ZeroGate::On);
    assert_eq!(first.output, after.output);

    // and gating must not perturb what execute reports against Off
    let off = pm.execute_gated(pm.seed_input(), par, ZeroGate::Off);
    assert_eq!(first.output, off.output);
    assert_eq!(first.act_sparsity, off.act_sparsity);
}

#[test]
fn profile_is_gating_invariant() {
    // measured sparsities must be identical whatever policy the model
    // defaults to — the twin's priced profile cannot depend on the gate
    let m = models::convnet5();
    let mut off = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
    off.set_zero_gate(ZeroGate::Off);
    let mut on = PreparedModel::prepare(&m, 3, 8, 42, Parallelism::serial());
    on.set_zero_gate(ZeroGate::On);
    let p_off = off.profile(Parallelism::serial());
    let p_on = on.profile(Parallelism::serial());
    for (a, b) in p_off.iter().zip(&p_on) {
        assert_eq!(a.act_sparsity.to_bits(), b.act_sparsity.to_bits(), "{}", a.name);
    }
}
